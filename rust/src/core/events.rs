//! Events (paper Appendix A): entities signalling that a particular state of
//! the environment has been reached. Rewards and terminations are defined
//! over events, which keeps both systems Markovian and composable.
//!
//! In the batched state each event is a per-env latch set by the
//! intervention/transition systems during the step and consumed by the
//! reward/termination systems at the end of it.

/// Per-env event latches for one step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Events {
    /// Player and a Goal entity share a position.
    pub goal_reached: bool,
    /// Player and a Lava entity share a position.
    pub lava_fall: bool,
    /// Player collided with a Ball (walked into it, or it moved onto the
    /// player) — the Dynamic-Obstacles failure event.
    pub ball_hit: bool,
    /// Player picked up the mission-target Ball (KeyCorridor success).
    pub ball_picked: bool,
    /// Player performed `done` facing a door of the mission colour
    /// (GoToDoor success).
    pub door_done: bool,
    /// A locked door was unlocked (Locked → Open transition; the Unlock
    /// family's success event).
    pub door_unlocked: bool,
    /// Player picked up the mission-target object of any pickable kind —
    /// key, ball or box (Fetch / UnlockPickup success).
    pub object_picked: bool,
    /// Player picked up a pickable that is *not* the mission target while a
    /// pickable mission is active (the Fetch failure event).
    pub wrong_pickup: bool,
    /// Player performed `done` facing the mission-target object of a
    /// pickable kind under a go-to mission (GoToObj success).
    pub object_reached: bool,
    /// Player dropped the mission-target object onto a cell 4-adjacent to
    /// the mission's second object (PutNext success).
    pub object_placed: bool,
    /// This agent walked into another agent's cell (the mover's side of a
    /// contested-cell conflict; the pursuit "tag" success event).
    pub agent_contact: bool,
    /// Another agent walked into this agent's cell (the target's side of
    /// a contested-cell conflict; the evader's failure event).
    pub contacted: bool,
    /// The fault-supervision layer quarantined this agent's slot this step
    /// (the step's mutations were rolled back to the pre-step snapshot, or
    /// the episode was replaced by a successor-key reset). Latched like
    /// `agent_contact` so trainers can deterministically mask the row's
    /// reward; *not* a terminal event, so [`Events::any`] ignores it.
    pub slot_quarantined: bool,
    /// Player toggled open a door matching the mission's active `Open`
    /// clause (SeqUnlockPickup / OpenDoorsOrder progress). A progress
    /// marker like `slot_quarantined` — mid-sequence clause completions
    /// must not terminate the episode, so [`Events::any`] ignores it.
    pub door_opened: bool,
    /// The mission's *final* clause completed this step — the success
    /// event sequenced families reward and terminate on.
    pub mission_complete: bool,
}

impl Events {
    pub const NONE: Events = Events {
        goal_reached: false,
        lava_fall: false,
        ball_hit: false,
        ball_picked: false,
        door_done: false,
        door_unlocked: false,
        object_picked: false,
        wrong_pickup: false,
        object_reached: false,
        object_placed: false,
        agent_contact: false,
        contacted: false,
        slot_quarantined: false,
        door_opened: false,
        mission_complete: false,
    };

    /// Any terminal-success/failure event fired this step?
    /// `slot_quarantined` and `door_opened` are deliberately excluded:
    /// the former is an engine-level recovery marker, and the latter a
    /// mid-sequence progress marker — neither is an episode outcome.
    #[inline]
    pub fn any(self) -> bool {
        self.goal_reached
            || self.lava_fall
            || self.ball_hit
            || self.ball_picked
            || self.door_done
            || self.door_unlocked
            || self.object_picked
            || self.wrong_pickup
            || self.object_reached
            || self.object_placed
            || self.agent_contact
            || self.contacted
            || self.mission_complete
    }

    /// Pack the latches into a bitmask (bit order = field order) for the
    /// [`crate::core::snapshot`] byte codec. Keep in sync with
    /// [`Events::from_bits`].
    pub fn to_bits(self) -> u16 {
        let fields = [
            self.goal_reached,
            self.lava_fall,
            self.ball_hit,
            self.ball_picked,
            self.door_done,
            self.door_unlocked,
            self.object_picked,
            self.wrong_pickup,
            self.object_reached,
            self.object_placed,
            self.agent_contact,
            self.contacted,
            self.slot_quarantined,
            self.door_opened,
            self.mission_complete,
        ];
        fields
            .iter()
            .enumerate()
            .fold(0u16, |acc, (i, &set)| acc | ((set as u16) << i))
    }

    /// Inverse of [`Events::to_bits`] (unknown high bits are ignored).
    pub fn from_bits(bits: u16) -> Events {
        let get = |i: usize| bits & (1 << i) != 0;
        Events {
            goal_reached: get(0),
            lava_fall: get(1),
            ball_hit: get(2),
            ball_picked: get(3),
            door_done: get(4),
            door_unlocked: get(5),
            object_picked: get(6),
            wrong_pickup: get(7),
            object_reached: get(8),
            object_placed: get(9),
            agent_contact: get(10),
            contacted: get(11),
            slot_quarantined: get(12),
            door_opened: get(13),
            mission_complete: get(14),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_none() {
        assert_eq!(Events::default(), Events::NONE);
        assert!(!Events::NONE.any());
    }

    #[test]
    fn any_detects_each_latch() {
        for i in 0..13 {
            let mut e = Events::NONE;
            match i {
                0 => e.goal_reached = true,
                1 => e.lava_fall = true,
                2 => e.ball_hit = true,
                3 => e.ball_picked = true,
                4 => e.door_done = true,
                5 => e.door_unlocked = true,
                6 => e.object_picked = true,
                7 => e.wrong_pickup = true,
                8 => e.object_reached = true,
                9 => e.object_placed = true,
                10 => e.agent_contact = true,
                11 => e.contacted = true,
                _ => e.mission_complete = true,
            }
            assert!(e.any());
        }
    }

    #[test]
    fn progress_latches_are_not_terminal() {
        let e = Events { slot_quarantined: true, ..Events::NONE };
        assert!(!e.any(), "a quarantine marker must never terminate an episode");
        let e = Events { door_opened: true, ..Events::NONE };
        assert!(!e.any(), "a mid-sequence clause completion must never terminate an episode");
    }

    #[test]
    fn bits_round_trip_every_latch() {
        for i in 0..15u16 {
            let e = Events::from_bits(1 << i);
            assert_eq!(e.to_bits(), 1 << i, "latch {i}");
            assert_eq!(Events::from_bits(e.to_bits()), e);
        }
        assert_eq!(Events::NONE.to_bits(), 0);
        let all = Events::from_bits(0x7FFF);
        assert_eq!(all.to_bits(), 0x7FFF);
        // v1 snapshot bitmasks (13 latches) decode with the new latches
        // cleared — byte-level back-compat for the codec.
        let v1 = Events::from_bits(0x1FFF);
        assert!(!v1.door_opened && !v1.mission_complete);
    }
}
