//! ECSM components (paper Table 1).
//!
//! Components are *properties* injected into entities: `Positionable`
//! (Position), `Directional` (Direction), `HasColour` (Colour), `Stochastic`
//! (Probability), `Openable` (State), `Pickable` (Id), `HasTag` (Tag),
//! `HasSprite` (Sprite) and `Holder` (Pocket). In this batched engine each
//! component value is stored as one element of a flat struct-of-arrays in
//! [`crate::core::state::BatchedState`]; the enums here define the value
//! vocabulary and its integer encoding, chosen to match MiniGrid's
//! `OBJECT_TO_IDX` / `COLOR_TO_IDX` / `STATE_TO_IDX` so that symbolic
//! observations are byte-compatible with the original suite.

/// Agent/entity facing. MiniGrid convention: 0=east(right), 1=south(down),
/// 2=west(left), 3=north(up).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(i32)]
pub enum Direction {
    East = 0,
    South = 1,
    West = 2,
    North = 3,
}

impl Direction {
    #[inline]
    pub fn from_i32(d: i32) -> Direction {
        match d.rem_euclid(4) {
            0 => Direction::East,
            1 => Direction::South,
            2 => Direction::West,
            _ => Direction::North,
        }
    }

    /// (dr, dc) unit vector.
    #[inline]
    pub fn vec(self) -> (i32, i32) {
        match self {
            Direction::East => (0, 1),
            Direction::South => (1, 0),
            Direction::West => (0, -1),
            Direction::North => (-1, 0),
        }
    }

    /// Rotate left (counter-clockwise), the MiniGrid `left` action.
    #[inline]
    pub fn left(self) -> Direction {
        Direction::from_i32(self as i32 + 3)
    }

    /// Rotate right (clockwise), the MiniGrid `right` action.
    #[inline]
    pub fn right(self) -> Direction {
        Direction::from_i32(self as i32 + 1)
    }

    /// The direction 90° clockwise from `self` (used for first-person frames).
    #[inline]
    pub fn rightward(self) -> Direction {
        self.right()
    }

    pub const ALL: [Direction; 4] =
        [Direction::East, Direction::South, Direction::West, Direction::North];
}

/// Entity colour (MiniGrid `COLOR_TO_IDX`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Color {
    Red = 0,
    Green = 1,
    Blue = 2,
    Purple = 3,
    Yellow = 4,
    Grey = 5,
}

impl Color {
    pub const ALL: [Color; 6] =
        [Color::Red, Color::Green, Color::Blue, Color::Purple, Color::Yellow, Color::Grey];

    #[inline]
    pub fn from_u8(c: u8) -> Color {
        Color::ALL[(c as usize) % 6]
    }

    /// RGB value used by the sprite renderer (MiniGrid palette).
    pub fn rgb(self) -> [u8; 3] {
        match self {
            Color::Red => [255, 0, 0],
            Color::Green => [0, 255, 0],
            Color::Blue => [0, 0, 255],
            Color::Purple => [112, 39, 195],
            Color::Yellow => [255, 255, 0],
            Color::Grey => [100, 100, 100],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Color::Red => "red",
            Color::Green => "green",
            Color::Blue => "blue",
            Color::Purple => "purple",
            Color::Yellow => "yellow",
            Color::Grey => "grey",
        }
    }
}

/// Openable-component state for doors (MiniGrid `STATE_TO_IDX`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum DoorState {
    Open = 0,
    Closed = 1,
    Locked = 2,
}

impl DoorState {
    #[inline]
    pub fn from_u8(s: u8) -> DoorState {
        match s {
            0 => DoorState::Open,
            1 => DoorState::Closed,
            _ => DoorState::Locked,
        }
    }
}

/// What the `Holder` component's Pocket can contain. Encoded in the batched
/// state as an `i32`: −1 = empty, otherwise `kind_tag << 8 | colour`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pocket(pub i32);

impl Pocket {
    pub const EMPTY: Pocket = Pocket(-1);

    #[inline]
    pub fn holding(kind_tag: i32, color: Color) -> Pocket {
        Pocket((kind_tag << 8) | color as i32)
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 < 0
    }

    #[inline]
    pub fn kind_tag(self) -> i32 {
        self.0 >> 8
    }

    #[inline]
    pub fn color(self) -> Color {
        Color::from_u8((self.0 & 0xFF) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direction_rotations_compose() {
        for d in Direction::ALL {
            assert_eq!(d.left().right(), d);
            assert_eq!(d.right().right().right().right(), d);
        }
        assert_eq!(Direction::East.right(), Direction::South);
        assert_eq!(Direction::East.left(), Direction::North);
    }

    #[test]
    fn direction_vectors_are_units() {
        for d in Direction::ALL {
            let (dr, dc) = d.vec();
            assert_eq!(dr.abs() + dc.abs(), 1);
        }
    }

    #[test]
    fn color_roundtrip() {
        for c in Color::ALL {
            assert_eq!(Color::from_u8(c as u8), c);
        }
    }

    #[test]
    fn door_state_roundtrip() {
        for s in [DoorState::Open, DoorState::Closed, DoorState::Locked] {
            assert_eq!(DoorState::from_u8(s as u8), s);
        }
    }

    #[test]
    fn pocket_encoding() {
        let p = Pocket::holding(5, Color::Yellow);
        assert!(!p.is_empty());
        assert_eq!(p.kind_tag(), 5);
        assert_eq!(p.color(), Color::Yellow);
        assert!(Pocket::EMPTY.is_empty());
    }
}
