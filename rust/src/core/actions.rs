//! The MiniGrid action space (7 discrete actions), shared by every NAVIX
//! environment.

/// MiniGrid's canonical action set, in index order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Action {
    /// Rotate counter-clockwise.
    Left = 0,
    /// Rotate clockwise.
    Right = 1,
    /// Move one cell forward if the target cell is walkable.
    Forward = 2,
    /// Pick up the pickable entity in the cell the agent is facing.
    Pickup = 3,
    /// Drop the held entity into the cell the agent is facing.
    Drop = 4,
    /// Toggle the entity ahead: open/close doors, unlock with a matching key.
    Toggle = 5,
    /// Declare task completion (used by GoToDoor-style missions).
    Done = 6,
}

impl Action {
    pub const N: usize = 7;

    pub const ALL: [Action; 7] = [
        Action::Left,
        Action::Right,
        Action::Forward,
        Action::Pickup,
        Action::Drop,
        Action::Toggle,
        Action::Done,
    ];

    #[inline]
    pub fn from_u8(a: u8) -> Action {
        Action::ALL[(a as usize) % Action::N]
    }

    pub fn name(self) -> &'static str {
        match self {
            Action::Left => "left",
            Action::Right => "right",
            Action::Forward => "forward",
            Action::Pickup => "pickup",
            Action::Drop => "drop",
            Action::Toggle => "toggle",
            Action::Done => "done",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_match_minigrid() {
        assert_eq!(Action::Left as u8, 0);
        assert_eq!(Action::Right as u8, 1);
        assert_eq!(Action::Forward as u8, 2);
        assert_eq!(Action::Pickup as u8, 3);
        assert_eq!(Action::Drop as u8, 4);
        assert_eq!(Action::Toggle as u8, 5);
        assert_eq!(Action::Done as u8, 6);
    }

    #[test]
    fn from_u8_roundtrip() {
        for a in Action::ALL {
            assert_eq!(Action::from_u8(a as u8), a);
        }
    }
}
