//! Slot-granular state serialization: the save/restore primitive behind
//! checkpointed training, quarantine recovery and time-travel debugging.
//!
//! A [`SlotSnapshot`] captures **every** SoA column of one environment slot
//! — the per-agent `[A]` columns (position/direction/pocket/mission/events/
//! last-action), the base grid and packed cell-code overlay rows, the
//! padded entity tables, the episode clock `t` and the in-episode RNG
//! stream state — so restoring it and stepping is bitwise identical to
//! never having left (pinned by `tests/test_snapshot.rs` across the whole
//! registry).
//!
//! [`SlotCheckpoint`] adds the engine-side bookkeeping a slot needs to
//! resume *mid-rollout*: the reset counter that derives successor episode
//! keys, and the slot's `[A]` timestep rows. [`EngineCheckpoint`] stacks
//! one of those per slot plus the engine root key and step counter; all
//! three engines expose it through
//! [`crate::batch::BatchStepper::save_checkpoint`].
//!
//! ## Byte format
//!
//! [`SlotSnapshot::to_bytes`] emits a little-endian, versioned, fixed-order
//! encoding: an 8-byte magic (`NVXSNAP` + version), the shape header
//! (`a, h, w, caps.{doors,keys,balls,boxes}` as u32), then each column in
//! declaration order (events as u16 bitmasks via
//! [`Events::to_bits`][crate::core::events::Events::to_bits]). No
//! compression, no external dependencies; [`SlotSnapshot::from_bytes`] is
//! the exact inverse and rejects wrong magic/shape/length with a
//! descriptive error string.

use super::events::Events;
use super::mission::{Mission, MissionSpec, MISSION_TOKENS};
use super::state::{BatchedState, Caps};
use super::timestep::StepType;

/// Magic prefix of the byte encoding: `NVXSNAP` + format version 2
/// (version 2 added the per-agent mission token slab; version 1 bytes
/// still decode, with the slab derived from the packed mission column).
const MAGIC: &[u8; 8] = b"NVXSNAP\x02";

/// The pre-grammar format: identical except no mission-token column.
const MAGIC_V1: &[u8; 8] = b"NVXSNAP\x01";

/// Bitwise image of one environment slot's full SoA state.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotSnapshot {
    /// Agents per slot (length of every per-agent column).
    pub a: usize,
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    // Grid columns, h*w each.
    pub base: Vec<u8>,
    pub base_color: Vec<u8>,
    pub overlay: Vec<u32>,
    pub overlay_idx: Vec<u8>,
    // Per-agent columns, a each.
    pub player_pos: Vec<i32>,
    pub player_dir: Vec<i32>,
    pub pocket: Vec<i32>,
    pub mission: Vec<i32>,
    /// Tokenised mission slab, `a * MISSION_TOKENS`.
    pub mission_tokens: Vec<i32>,
    pub events: Vec<Events>,
    pub last_action: Vec<i32>,
    // Entity tables, caps.* each.
    pub door_pos: Vec<i32>,
    pub door_color: Vec<u8>,
    pub door_state: Vec<u8>,
    pub key_pos: Vec<i32>,
    pub key_color: Vec<u8>,
    pub ball_pos: Vec<i32>,
    pub ball_color: Vec<u8>,
    pub box_pos: Vec<i32>,
    pub box_color: Vec<u8>,
    // Episode bookkeeping.
    pub t: u32,
    /// The in-episode RNG stream state (`BatchedState::rng[i]`).
    pub rng: u64,
}

impl SlotSnapshot {
    /// Capture slot `i` of `state`.
    pub fn capture(state: &BatchedState, i: usize) -> SlotSnapshot {
        assert!(i < state.b, "slot {i} out of range (b = {})", state.b);
        let hw = state.h * state.w;
        let a = state.a;
        let c = state.caps;
        let grid = |v: &Vec<u8>| v[i * hw..(i + 1) * hw].to_vec();
        SlotSnapshot {
            a,
            h: state.h,
            w: state.w,
            caps: c,
            base: grid(&state.base),
            base_color: grid(&state.base_color),
            overlay: state.overlay[i * hw..(i + 1) * hw].to_vec(),
            overlay_idx: grid(&state.overlay_idx),
            player_pos: state.player_pos[i * a..(i + 1) * a].to_vec(),
            player_dir: state.player_dir[i * a..(i + 1) * a].to_vec(),
            pocket: state.pocket[i * a..(i + 1) * a].to_vec(),
            mission: state.mission[i * a..(i + 1) * a].to_vec(),
            mission_tokens: state.mission_tokens
                [i * a * MISSION_TOKENS..(i + 1) * a * MISSION_TOKENS]
                .to_vec(),
            events: state.events[i * a..(i + 1) * a].to_vec(),
            last_action: state.last_action[i * a..(i + 1) * a].to_vec(),
            door_pos: state.door_pos[i * c.doors..(i + 1) * c.doors].to_vec(),
            door_color: state.door_color[i * c.doors..(i + 1) * c.doors].to_vec(),
            door_state: state.door_state[i * c.doors..(i + 1) * c.doors].to_vec(),
            key_pos: state.key_pos[i * c.keys..(i + 1) * c.keys].to_vec(),
            key_color: state.key_color[i * c.keys..(i + 1) * c.keys].to_vec(),
            ball_pos: state.ball_pos[i * c.balls..(i + 1) * c.balls].to_vec(),
            ball_color: state.ball_color[i * c.balls..(i + 1) * c.balls].to_vec(),
            box_pos: state.box_pos[i * c.boxes..(i + 1) * c.boxes].to_vec(),
            box_color: state.box_color[i * c.boxes..(i + 1) * c.boxes].to_vec(),
            t: state.t[i],
            rng: state.rng[i],
        }
    }

    /// Restore this snapshot into slot `i` of `state`. Panics if the
    /// state's shape (agents, grid, capacities) differs from the
    /// snapshot's — a snapshot only fits the configuration it came from.
    pub fn restore(&self, state: &mut BatchedState, i: usize) {
        assert!(i < state.b, "slot {i} out of range (b = {})", state.b);
        assert_eq!(
            (self.a, self.h, self.w, self.caps),
            (state.a, state.h, state.w, state.caps),
            "snapshot shape mismatch: snapshot was taken on a different env configuration"
        );
        let hw = state.h * state.w;
        let a = state.a;
        let c = state.caps;
        state.base[i * hw..(i + 1) * hw].copy_from_slice(&self.base);
        state.base_color[i * hw..(i + 1) * hw].copy_from_slice(&self.base_color);
        state.overlay[i * hw..(i + 1) * hw].copy_from_slice(&self.overlay);
        state.overlay_idx[i * hw..(i + 1) * hw].copy_from_slice(&self.overlay_idx);
        state.player_pos[i * a..(i + 1) * a].copy_from_slice(&self.player_pos);
        state.player_dir[i * a..(i + 1) * a].copy_from_slice(&self.player_dir);
        state.pocket[i * a..(i + 1) * a].copy_from_slice(&self.pocket);
        state.mission[i * a..(i + 1) * a].copy_from_slice(&self.mission);
        state.mission_tokens[i * a * MISSION_TOKENS..(i + 1) * a * MISSION_TOKENS]
            .copy_from_slice(&self.mission_tokens);
        state.events[i * a..(i + 1) * a].copy_from_slice(&self.events);
        state.last_action[i * a..(i + 1) * a].copy_from_slice(&self.last_action);
        state.door_pos[i * c.doors..(i + 1) * c.doors].copy_from_slice(&self.door_pos);
        state.door_color[i * c.doors..(i + 1) * c.doors].copy_from_slice(&self.door_color);
        state.door_state[i * c.doors..(i + 1) * c.doors].copy_from_slice(&self.door_state);
        state.key_pos[i * c.keys..(i + 1) * c.keys].copy_from_slice(&self.key_pos);
        state.key_color[i * c.keys..(i + 1) * c.keys].copy_from_slice(&self.key_color);
        state.ball_pos[i * c.balls..(i + 1) * c.balls].copy_from_slice(&self.ball_pos);
        state.ball_color[i * c.balls..(i + 1) * c.balls].copy_from_slice(&self.ball_color);
        state.box_pos[i * c.boxes..(i + 1) * c.boxes].copy_from_slice(&self.box_pos);
        state.box_color[i * c.boxes..(i + 1) * c.boxes].copy_from_slice(&self.box_color);
        state.t[i] = self.t;
        state.rng[i] = self.rng;
    }

    /// Serialize to the versioned little-endian byte format (module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 10 * self.h * self.w);
        out.extend_from_slice(MAGIC);
        for dim in [
            self.a,
            self.h,
            self.w,
            self.caps.doors,
            self.caps.keys,
            self.caps.balls,
            self.caps.boxes,
        ] {
            out.extend_from_slice(&(dim as u32).to_le_bytes());
        }
        out.extend_from_slice(&self.base);
        out.extend_from_slice(&self.base_color);
        for &x in &self.overlay {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&self.overlay_idx);
        for col in [
            &self.player_pos,
            &self.player_dir,
            &self.pocket,
            &self.mission,
            &self.mission_tokens,
            &self.last_action,
        ] {
            for &x in col.iter() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        for &e in &self.events {
            out.extend_from_slice(&e.to_bits().to_le_bytes());
        }
        for col in [&self.door_pos, &self.key_pos, &self.ball_pos, &self.box_pos] {
            for &x in col.iter() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out.extend_from_slice(&self.door_color);
        out.extend_from_slice(&self.door_state);
        out.extend_from_slice(&self.key_color);
        out.extend_from_slice(&self.ball_color);
        out.extend_from_slice(&self.box_color);
        out.extend_from_slice(&self.t.to_le_bytes());
        out.extend_from_slice(&self.rng.to_le_bytes());
        out
    }

    /// Decode [`SlotSnapshot::to_bytes`] output. Errors (instead of
    /// panicking) on wrong magic/version or a truncated/oversized buffer.
    /// Version 1 (pre-grammar) bytes still decode: their token slab is
    /// derived from the packed mission column via the lossless 1-clause
    /// embedding.
    pub fn from_bytes(bytes: &[u8]) -> Result<SlotSnapshot, String> {
        let mut r = Reader { buf: bytes, at: 0 };
        let magic = r.take(8)?;
        let v1 = magic == MAGIC_V1;
        if !v1 && magic != MAGIC {
            return Err(format!("bad snapshot magic/version: {magic:02x?}"));
        }
        let a = r.u32()? as usize;
        let h = r.u32()? as usize;
        let w = r.u32()? as usize;
        let caps = Caps {
            doors: r.u32()? as usize,
            keys: r.u32()? as usize,
            balls: r.u32()? as usize,
            boxes: r.u32()? as usize,
        };
        let hw = h * w;
        let base = r.take(hw)?.to_vec();
        let base_color = r.take(hw)?.to_vec();
        let overlay = r.u32_vec(hw)?;
        let overlay_idx = r.take(hw)?.to_vec();
        let player_pos = r.i32_vec(a)?;
        let player_dir = r.i32_vec(a)?;
        let pocket = r.i32_vec(a)?;
        let mission = r.i32_vec(a)?;
        let mission_tokens = if v1 {
            let mut slab = vec![0i32; a * MISSION_TOKENS];
            for (j, &m) in mission.iter().enumerate() {
                MissionSpec::from_mission(Mission::from_raw(m))
                    .write_tokens(&mut slab[j * MISSION_TOKENS..(j + 1) * MISSION_TOKENS]);
            }
            slab
        } else {
            r.i32_vec(a * MISSION_TOKENS)?
        };
        let snap = SlotSnapshot {
            a,
            h,
            w,
            caps,
            base,
            base_color,
            overlay,
            overlay_idx,
            player_pos,
            player_dir,
            pocket,
            mission,
            mission_tokens,
            last_action: r.i32_vec(a)?,
            events: {
                let mut v = Vec::with_capacity(a);
                for _ in 0..a {
                    v.push(Events::from_bits(r.u16()?));
                }
                v
            },
            door_pos: r.i32_vec(caps.doors)?,
            key_pos: r.i32_vec(caps.keys)?,
            ball_pos: r.i32_vec(caps.balls)?,
            box_pos: r.i32_vec(caps.boxes)?,
            door_color: r.take(caps.doors)?.to_vec(),
            door_state: r.take(caps.doors)?.to_vec(),
            key_color: r.take(caps.keys)?.to_vec(),
            ball_color: r.take(caps.balls)?.to_vec(),
            box_color: r.take(caps.boxes)?.to_vec(),
            t: r.u32()?,
            rng: r.u64()?,
        };
        if r.at != bytes.len() {
            return Err(format!(
                "snapshot buffer has {} trailing bytes",
                bytes.len() - r.at
            ));
        }
        Ok(snap)
    }
}

/// Bounds-checked little-endian cursor for [`SlotSnapshot::from_bytes`].
struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.at + n > self.buf.len() {
            return Err(format!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>, String> {
        (0..n).map(|_| self.u32()).collect()
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>, String> {
        Ok(self.u32_vec(n)?.into_iter().map(|x| x as i32).collect())
    }
}

/// A [`SlotSnapshot`] plus the engine-side bookkeeping needed to resume the
/// slot *mid-rollout*: the reset counter (successor episode keys derive
/// from it) and the slot's `[A]` timestep rows.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotCheckpoint {
    pub state: SlotSnapshot,
    /// `BatchedEnv::reset_counts[i]` — restoring it keeps the successor
    /// episode-key sequence aligned with an uninterrupted run.
    pub reset_count: u64,
    // The slot's [A] timestep rows, in BatchedTimestep field order.
    pub ts_t: Vec<u32>,
    pub ts_action: Vec<i32>,
    pub ts_reward: Vec<f32>,
    pub ts_discount: Vec<f32>,
    pub ts_step_type: Vec<StepType>,
    pub ts_episodic_return: Vec<f32>,
}

/// All `B` slots of an engine plus the engine-level RNG identity and step
/// counter: everything `restore_checkpoint` needs to make a fresh engine
/// of the same configuration continue bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineCheckpoint {
    pub b: usize,
    pub a: usize,
    /// The engine root key (episode keys fold slot index + reset count
    /// into it); restore asserts it matches the target engine's.
    pub root_key: u64,
    /// Engine steps taken so far (drives the chaos injector's clock).
    pub step_count: u64,
    pub slots: Vec<SlotCheckpoint>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::{Color, Direction, DoorState};
    use crate::core::grid::Pos;

    fn populated_state() -> BatchedState {
        let mut st = BatchedState::with_agents(
            2,
            5,
            6,
            Caps { doors: 2, keys: 2, balls: 1, boxes: 1 },
            2,
        );
        let mut s = st.agent_slot_mut(1, 0);
        s.fill_room();
        *s.rng = 0xDEAD_BEEF;
        *s.t = 17;
        s.place_player(Pos::new(1, 1), Direction::East);
        s.place_agent(1, Pos::new(3, 3), Direction::North);
        s.add_door(Pos::new(2, 3), Color::Yellow, DoorState::Locked);
        s.add_key(Pos::new(1, 2), Color::Yellow);
        s.add_ball(Pos::new(3, 2), Color::Blue);
        s.set_mission(Mission::go_to(crate::core::entities::Tag::DOOR, Color::Yellow));
        s.events[1].goal_reached = true;
        s.last_action[0] = 2;
        st
    }

    #[test]
    fn capture_restore_round_trips_bitwise() {
        let st = populated_state();
        let snap = SlotSnapshot::capture(&st, 1);
        // Restore into a freshly allocated state and compare every column.
        let mut dst = BatchedState::with_agents(2, 5, 6, st.caps, 2);
        snap.restore(&mut dst, 1);
        assert_eq!(SlotSnapshot::capture(&dst, 1), snap);
        // The neighbouring slot is untouched (still the zeroed allocation).
        let zero = BatchedState::with_agents(2, 5, 6, st.caps, 2);
        assert_eq!(SlotSnapshot::capture(&dst, 0), SlotSnapshot::capture(&zero, 0));
    }

    #[test]
    fn byte_codec_round_trips_bitwise() {
        let st = populated_state();
        for i in 0..st.b {
            let snap = SlotSnapshot::capture(&st, i);
            let bytes = snap.to_bytes();
            let back = SlotSnapshot::from_bytes(&bytes).expect("decode");
            assert_eq!(back, snap, "slot {i}");
        }
    }

    #[test]
    fn byte_codec_rejects_garbage() {
        let st = populated_state();
        let bytes = SlotSnapshot::capture(&st, 0).to_bytes();
        assert!(SlotSnapshot::from_bytes(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(SlotSnapshot::from_bytes(&extra).is_err(), "trailing bytes");
        let mut bad = bytes;
        bad[7] = 99; // version byte
        assert!(SlotSnapshot::from_bytes(&bad).is_err(), "bad version");
    }

    #[test]
    fn v1_bytes_still_restore() {
        // A version-1 buffer is the v2 layout minus the mission-token
        // column (which sat between `mission` and `last_action`). Splice
        // the slab out of a v2 buffer and patch the version byte: decoding
        // must succeed and re-derive the slab from the packed missions.
        let st = populated_state();
        for i in 0..st.b {
            let snap = SlotSnapshot::capture(&st, i);
            let bytes = snap.to_bytes();
            let hw = snap.h * snap.w;
            let a = snap.a;
            // offset of the token column: magic + 7 dims + base + base_color
            // + overlay(u32) + overlay_idx + 4 i32 cols (pos/dir/pocket/mission)
            let tok_at = 8 + 7 * 4 + hw + hw + 4 * hw + hw + 4 * a * 4;
            let tok_len = a * MISSION_TOKENS * 4;
            let mut v1 = Vec::with_capacity(bytes.len() - tok_len);
            v1.extend_from_slice(&bytes[..tok_at]);
            v1.extend_from_slice(&bytes[tok_at + tok_len..]);
            v1[7] = 1;
            let back = SlotSnapshot::from_bytes(&v1).expect("v1 decode");
            assert_eq!(back, snap, "slot {i}: v1 bytes restore bit-for-bit");
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn restore_rejects_shape_mismatch() {
        let st = populated_state();
        let snap = SlotSnapshot::capture(&st, 0);
        let mut other = BatchedState::new(2, 7, 7, Caps::default());
        snap.restore(&mut other, 0);
    }
}
