//! The batched environment state: a struct-of-arrays over the batch axis.
//!
//! This is the Rust analog of NAVIX's vmapped PyTree state. Every ECSM
//! component (paper Table 1) is a flat array with one element (or one
//! fixed-capacity block) per environment, so the batched stepper touches
//! contiguous memory and entity capacities are *static per configuration* —
//! the same static-shape constraint `jax.vmap`/`jit` imposes on the original
//! implementation.
//!
//! Dynamic entities (doors, keys, balls, boxes) use fixed capacities with
//! position −1 meaning "absent" (mirroring NAVIX's padded entity arrays).
//!
//! ## The packed cell-code overlay grid
//!
//! On top of the entity tables the state maintains a write-through **overlay
//! grid**: per cell, one `u32` [`cellcode`] packing the `(tag, colour,
//! state)` triple the observation encoding would produce for that cell
//! (player excluded — the player is overlaid by the observation writers),
//! plus one `u8` entity-table index for the queries that still need the
//! table. Base terrain is pre-merged with the entity overlay, so the spatial
//! queries (`door_at`, `walkable`, `opaque`, `occupied_by_entity`,
//! `free_for_placement`) and the per-cell observation encoding are O(1)
//! array reads instead of O(caps) scans — the per-step observation cost
//! drops from O(H·W·caps) to O(H·W) (see `EXPERIMENTS.md` §Perf).
//!
//! The overlay is kept incrementally consistent by routing **every**
//! mutation through the [`SlotMut`] write-through setters (`set_cell`,
//! `add_*`/`try_add_*`, `set_door_state`, `remove_*`, `move_ball`, …):
//! each setter recomputes the affected cell(s) from the tables with the
//! original first-match scans (`door_at_scan` & co., kept as the
//! bitwise-parity oracle), so a mutation costs O(caps) once instead of
//! every observation paying O(caps) per cell per step.

use super::components::{Color, Direction, DoorState, Pocket};
use super::entities::{CellType, Tag};
use super::events::Events;
use super::grid::{GridDims, Pos};
use super::mission::{Mission, MissionSpec, MISSION_TOKENS};
use crate::rng::Rng;

/// The packed per-cell overlay code: `tag | colour << 8 | state << 16`,
/// exactly the `(tag, colour, state)` triple MiniGrid's `encode` produces
/// for the cell (player excluded). `u32::MAX` is reserved as an "invalid"
/// sentinel for the rgb dirty-tile caches (no real code reaches it: tags
/// are ≤ 10).
pub mod cellcode {
    use super::super::entities::{CellType, Tag};

    /// "No entity on this cell" marker for the index channel.
    pub const NONE_IDX: u8 = u8::MAX;
    /// Dirty-tile sentinel: never produced by [`pack`], forces a re-blit.
    pub const INVALID: u32 = u32::MAX;

    #[inline]
    pub const fn pack(tag: i32, color: u8, state: u8) -> u32 {
        (tag as u32) | ((color as u32) << 8) | ((state as u32) << 16)
    }

    #[inline]
    pub const fn tag(code: u32) -> i32 {
        (code & 0xFF) as i32
    }

    #[inline]
    pub const fn color(code: u32) -> i32 {
        ((code >> 8) & 0xFF) as i32
    }

    #[inline]
    pub const fn state(code: u32) -> i32 {
        ((code >> 16) & 0xFF) as i32
    }

    /// Code of a bare base-terrain cell — the exact triple the naive
    /// `encode_cell` match produces (goal colour is pinned to green, floor
    /// and lava to colour 0, matching MiniGrid's `encode`).
    #[inline]
    pub fn base_code(cell: CellType, base_color: u8) -> u32 {
        match cell {
            CellType::Floor => pack(Tag::EMPTY, 0, 0),
            CellType::Wall => pack(Tag::WALL, base_color, 0),
            CellType::Goal => pack(Tag::GOAL, 1, 0),
            CellType::Lava => pack(Tag::LAVA, 0, 0),
        }
    }
}

/// Static entity capacities for one environment configuration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Caps {
    pub doors: usize,
    pub keys: usize,
    pub balls: usize,
    pub boxes: usize,
}

/// Struct-of-arrays state for `b` parallel environments of size `h × w`,
/// each hosting `a` agents (the agent axis; `a = 1` is the classic
/// single-agent suite and collapses every `[B × A]` column to the old
/// `[B]` shape exactly).
#[derive(Clone, Debug)]
pub struct BatchedState {
    pub b: usize,
    /// Agents per environment slot. Agent `j` of env `i` lives at flat
    /// row `i·a + j` in every per-agent column (agent-major rows).
    pub a: usize,
    pub h: usize,
    pub w: usize,
    pub caps: Caps,

    // Base grid (static per episode): cell types + colours, b*h*w each.
    pub base: Vec<u8>,
    pub base_color: Vec<u8>,

    // Packed cell-code overlay (base terrain pre-merged with the entity
    // overlay, player excluded) + the entity-table index channel, b*h*w
    // each. Kept write-through consistent by the `SlotMut` setters; never
    // poke entity tables or `base` directly.
    pub overlay: Vec<u32>,
    pub overlay_idx: Vec<u8>,

    // Agents (Positionable + Directional + Holder), b*a each; position −1
    // means "unplaced" (extra agents of an A=1 state never exist).
    pub player_pos: Vec<i32>,
    pub player_dir: Vec<i32>,
    pub pocket: Vec<i32>,

    // Doors (Positionable + Openable + HasColour), b*caps.doors each.
    pub door_pos: Vec<i32>,
    pub door_color: Vec<u8>,
    pub door_state: Vec<u8>,

    // Keys (Positionable + Pickable + HasColour), b*caps.keys each.
    pub key_pos: Vec<i32>,
    pub key_color: Vec<u8>,

    // Balls (Positionable + HasColour + Stochastic), b*caps.balls each.
    pub ball_pos: Vec<i32>,
    pub ball_color: Vec<u8>,

    // Boxes (Positionable + HasColour), b*caps.boxes each.
    pub box_pos: Vec<i32>,
    pub box_color: Vec<u8>,

    // Episode bookkeeping: t/rng are per env (one episode clock and one
    // RNG stream per slot); mission/events/last_action are per agent
    // (b*a) so rewards and terminations can be evaluated agent by agent.
    pub t: Vec<u32>,
    pub mission: Vec<i32>,
    /// Tokenised mission slab, `b*a*MISSION_TOKENS` (row = one agent's
    /// serialised [`MissionSpec`]). `mission` always holds the *active*
    /// clause's packed `i32` — the slab is the full grammar (clause list,
    /// cursor, completion latches) and the block observations stream.
    pub mission_tokens: Vec<i32>,
    pub rng: Vec<u64>,
    pub events: Vec<Events>,
    pub last_action: Vec<i32>,
}

impl BatchedState {
    /// Allocate a zeroed single-agent batched state.
    pub fn new(b: usize, h: usize, w: usize, caps: Caps) -> Self {
        Self::with_agents(b, h, w, caps, 1)
    }

    /// Allocate a zeroed batched state with `a` agents per slot.
    pub fn with_agents(b: usize, h: usize, w: usize, caps: Caps, a: usize) -> Self {
        assert!(a >= 1, "a slot hosts at least one agent");
        let hw = h * w;
        BatchedState {
            b,
            a,
            h,
            w,
            caps,
            base: vec![CellType::Wall as u8; b * hw],
            base_color: vec![Color::Grey as u8; b * hw],
            overlay: vec![cellcode::base_code(CellType::Wall, Color::Grey as u8); b * hw],
            overlay_idx: vec![cellcode::NONE_IDX; b * hw],
            player_pos: vec![-1; b * a],
            player_dir: vec![0; b * a],
            pocket: vec![-1; b * a],
            door_pos: vec![-1; b * caps.doors],
            door_color: vec![0; b * caps.doors],
            door_state: vec![DoorState::Closed as u8; b * caps.doors],
            key_pos: vec![-1; b * caps.keys],
            key_color: vec![0; b * caps.keys],
            ball_pos: vec![-1; b * caps.balls],
            ball_color: vec![0; b * caps.balls],
            box_pos: vec![-1; b * caps.boxes],
            box_color: vec![0; b * caps.boxes],
            t: vec![0; b],
            mission: vec![-1; b * a],
            mission_tokens: vec![0; b * a * MISSION_TOKENS],
            rng: vec![0; b],
            events: vec![Events::NONE; b * a],
            last_action: vec![-1; b * a],
        }
    }

    #[inline]
    pub fn dims(&self) -> GridDims {
        GridDims::new(self.h, self.w)
    }

    /// Mutable per-env view acting as agent 0 (the classic single-agent
    /// entry point; disjoint field borrows, one env at a time).
    #[inline]
    pub fn slot_mut(&mut self, i: usize) -> SlotMut<'_> {
        self.agent_slot_mut(i, 0)
    }

    /// Mutable per-env view acting as agent `j` of env `i`. The view
    /// carries the whole `[A]` agent column of its slot (so conflict
    /// checks see every agent) plus the active agent index.
    #[inline]
    pub fn agent_slot_mut(&mut self, i: usize, j: usize) -> SlotMut<'_> {
        debug_assert!(j < self.a);
        let hw = self.h * self.w;
        let c = self.caps;
        let a = self.a;
        SlotMut {
            h: self.h,
            w: self.w,
            caps: c,
            agent: j,
            base: &mut self.base[i * hw..(i + 1) * hw],
            base_color: &mut self.base_color[i * hw..(i + 1) * hw],
            overlay: &mut self.overlay[i * hw..(i + 1) * hw],
            overlay_idx: &mut self.overlay_idx[i * hw..(i + 1) * hw],
            player_pos: &mut self.player_pos[i * a..(i + 1) * a],
            player_dir: &mut self.player_dir[i * a..(i + 1) * a],
            pocket: &mut self.pocket[i * a..(i + 1) * a],
            door_pos: &mut self.door_pos[i * c.doors..(i + 1) * c.doors],
            door_color: &mut self.door_color[i * c.doors..(i + 1) * c.doors],
            door_state: &mut self.door_state[i * c.doors..(i + 1) * c.doors],
            key_pos: &mut self.key_pos[i * c.keys..(i + 1) * c.keys],
            key_color: &mut self.key_color[i * c.keys..(i + 1) * c.keys],
            ball_pos: &mut self.ball_pos[i * c.balls..(i + 1) * c.balls],
            ball_color: &mut self.ball_color[i * c.balls..(i + 1) * c.balls],
            box_pos: &mut self.box_pos[i * c.boxes..(i + 1) * c.boxes],
            box_color: &mut self.box_color[i * c.boxes..(i + 1) * c.boxes],
            t: &mut self.t[i],
            mission: &mut self.mission[i * a..(i + 1) * a],
            mission_tokens: &mut self.mission_tokens
                [i * a * MISSION_TOKENS..(i + 1) * a * MISSION_TOKENS],
            rng: &mut self.rng[i],
            events: &mut self.events[i * a..(i + 1) * a],
            last_action: &mut self.last_action[i * a..(i + 1) * a],
        }
    }

    /// Immutable per-env view acting as agent 0.
    #[inline]
    pub fn slot(&self, i: usize) -> EnvSlot<'_> {
        self.agent_slot(i, 0)
    }

    /// Immutable per-env view acting as agent `j` of env `i`.
    #[inline]
    pub fn agent_slot(&self, i: usize, j: usize) -> EnvSlot<'_> {
        debug_assert!(j < self.a);
        let hw = self.h * self.w;
        let c = self.caps;
        let a = self.a;
        EnvSlot {
            h: self.h,
            w: self.w,
            caps: c,
            agent: j,
            base: &self.base[i * hw..(i + 1) * hw],
            base_color: &self.base_color[i * hw..(i + 1) * hw],
            overlay: &self.overlay[i * hw..(i + 1) * hw],
            overlay_idx: &self.overlay_idx[i * hw..(i + 1) * hw],
            player_pos: &self.player_pos[i * a..(i + 1) * a],
            player_dir: &self.player_dir[i * a..(i + 1) * a],
            pocket: &self.pocket[i * a..(i + 1) * a],
            door_pos: &self.door_pos[i * c.doors..(i + 1) * c.doors],
            door_color: &self.door_color[i * c.doors..(i + 1) * c.doors],
            door_state: &self.door_state[i * c.doors..(i + 1) * c.doors],
            key_pos: &self.key_pos[i * c.keys..(i + 1) * c.keys],
            key_color: &self.key_color[i * c.keys..(i + 1) * c.keys],
            ball_pos: &self.ball_pos[i * c.balls..(i + 1) * c.balls],
            ball_color: &self.ball_color[i * c.balls..(i + 1) * c.balls],
            box_pos: &self.box_pos[i * c.boxes..(i + 1) * c.boxes],
            box_color: &self.box_color[i * c.boxes..(i + 1) * c.boxes],
            t: self.t[i],
            mission: &self.mission[i * a..(i + 1) * a],
            mission_tokens: &self.mission_tokens
                [i * a * MISSION_TOKENS..(i + 1) * a * MISSION_TOKENS],
            events: &self.events[i * a..(i + 1) * a],
            last_action: &self.last_action[i * a..(i + 1) * a],
        }
    }
}

/// Immutable view over one environment's state, acting as one agent.
/// The per-agent fields are the slot's whole `[A]` columns; `agent`
/// selects the active row (`player()`, `dir()`, … decode that row).
#[derive(Clone, Copy)]
pub struct EnvSlot<'a> {
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    /// Which agent of the slot this view acts as.
    pub agent: usize,
    pub base: &'a [u8],
    pub base_color: &'a [u8],
    pub overlay: &'a [u32],
    pub overlay_idx: &'a [u8],
    pub player_pos: &'a [i32],
    pub player_dir: &'a [i32],
    pub pocket: &'a [i32],
    pub door_pos: &'a [i32],
    pub door_color: &'a [u8],
    pub door_state: &'a [u8],
    pub key_pos: &'a [i32],
    pub key_color: &'a [u8],
    pub ball_pos: &'a [i32],
    pub ball_color: &'a [u8],
    pub box_pos: &'a [i32],
    pub box_color: &'a [u8],
    pub t: u32,
    pub mission: &'a [i32],
    pub mission_tokens: &'a [i32],
    pub events: &'a [Events],
    pub last_action: &'a [i32],
}

/// Mutable view over one environment's state, acting as one agent.
pub struct SlotMut<'a> {
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    /// Which agent of the slot this view acts as.
    pub agent: usize,
    pub base: &'a mut [u8],
    pub base_color: &'a mut [u8],
    pub overlay: &'a mut [u32],
    pub overlay_idx: &'a mut [u8],
    pub player_pos: &'a mut [i32],
    pub player_dir: &'a mut [i32],
    pub pocket: &'a mut [i32],
    pub door_pos: &'a mut [i32],
    pub door_color: &'a mut [u8],
    pub door_state: &'a mut [u8],
    pub key_pos: &'a mut [i32],
    pub key_color: &'a mut [u8],
    pub ball_pos: &'a mut [i32],
    pub ball_color: &'a mut [u8],
    pub box_pos: &'a mut [i32],
    pub box_color: &'a mut [u8],
    pub t: &'a mut u32,
    pub mission: &'a mut [i32],
    pub mission_tokens: &'a mut [i32],
    pub rng: &'a mut u64,
    pub events: &'a mut [Events],
    pub last_action: &'a mut [i32],
}

/// Shared agent-axis accessors over the two per-env views: the required
/// methods expose each view's `[A]` columns once, and every derived
/// accessor (the active agent's decoded position/direction/pocket/
/// mission, occupancy probes for conflict resolution) is written once
/// here instead of per view — this trait replaces the accessor
/// boilerplate [`EnvSlot`] and [`SlotMut`] used to duplicate.
pub trait AgentView {
    /// Per-agent encoded positions `[A]` (−1 = unplaced).
    fn pos_col(&self) -> &[i32];
    /// Per-agent facing directions `[A]`.
    fn dir_col(&self) -> &[i32];
    /// Per-agent packed pockets `[A]`.
    fn pocket_col(&self) -> &[i32];
    /// Per-agent packed missions `[A]` (the *active* clause of each
    /// agent's [`MissionSpec`]).
    fn mission_col(&self) -> &[i32];
    /// Per-agent tokenised mission slab `[A × MISSION_TOKENS]`.
    fn mission_tokens_col(&self) -> &[i32];
    /// Per-agent event latches `[A]`.
    fn events_col(&self) -> &[Events];
    /// The agent this view acts as.
    fn active_agent(&self) -> usize;
    /// Grid height (occupancy probes bounds-check before flat-encoding).
    fn grid_h(&self) -> usize;
    /// Grid width (positions are flat-encoded against it).
    fn grid_w(&self) -> usize;

    /// Number of agents in this slot.
    #[inline]
    fn agent_count(&self) -> usize {
        self.pos_col().len()
    }

    /// The active agent's encoded position.
    #[inline]
    fn player_pos_value(&self) -> i32 {
        self.pos_col()[self.active_agent()]
    }

    /// The active agent's encoded direction.
    #[inline]
    fn player_dir_value(&self) -> i32 {
        self.dir_col()[self.active_agent()]
    }

    /// The active agent's packed pocket.
    #[inline]
    fn pocket_raw(&self) -> i32 {
        self.pocket_col()[self.active_agent()]
    }

    /// The active agent's packed mission (the active clause).
    #[inline]
    fn mission_raw(&self) -> i32 {
        self.mission_col()[self.active_agent()]
    }

    /// The active agent's mission token row (`MISSION_TOKENS` wide) —
    /// exactly the block the observation system streams to the policy.
    #[inline]
    fn mission_tokens_row(&self) -> &[i32] {
        let j = self.active_agent();
        &self.mission_tokens_col()[j * MISSION_TOKENS..(j + 1) * MISSION_TOKENS]
    }

    /// The active agent's full mission grammar, decoded from the slab.
    #[inline]
    fn mission_spec(&self) -> MissionSpec {
        MissionSpec::from_tokens(self.mission_tokens_row())
    }

    /// The active agent's event latches.
    #[inline]
    fn events_value(&self) -> Events {
        self.events_col()[self.active_agent()]
    }

    /// Agent `j`'s decoded position.
    #[inline]
    fn agent_pos(&self, j: usize) -> Pos {
        Pos::decode(self.pos_col()[j], self.grid_w())
    }

    /// Index of the (placed) agent standing on `p`, if any. Bounds-checks
    /// first: an out-of-bounds `p` must not flat-encode onto a real row
    /// (`r·W + c` with `c ≥ W` aliases into the next row).
    #[inline]
    fn agent_at(&self, p: Pos) -> Option<usize> {
        if !p.in_bounds(self.grid_h(), self.grid_w()) {
            return None;
        }
        let enc = p.encode(self.grid_w());
        self.pos_col().iter().position(|&x| x >= 0 && x == enc)
    }

    /// Index of an agent *other than the active one* standing on `p`.
    #[inline]
    fn other_agent_at(&self, p: Pos) -> Option<usize> {
        self.agent_at(p).filter(|&j| j != self.active_agent())
    }
}

impl<'a> AgentView for EnvSlot<'a> {
    #[inline]
    fn pos_col(&self) -> &[i32] {
        self.player_pos
    }
    #[inline]
    fn dir_col(&self) -> &[i32] {
        self.player_dir
    }
    #[inline]
    fn pocket_col(&self) -> &[i32] {
        self.pocket
    }
    #[inline]
    fn mission_col(&self) -> &[i32] {
        self.mission
    }
    #[inline]
    fn mission_tokens_col(&self) -> &[i32] {
        self.mission_tokens
    }
    #[inline]
    fn events_col(&self) -> &[Events] {
        self.events
    }
    #[inline]
    fn active_agent(&self) -> usize {
        self.agent
    }
    #[inline]
    fn grid_h(&self) -> usize {
        self.h
    }
    #[inline]
    fn grid_w(&self) -> usize {
        self.w
    }
}

impl<'a> AgentView for SlotMut<'a> {
    #[inline]
    fn pos_col(&self) -> &[i32] {
        &*self.player_pos
    }
    #[inline]
    fn dir_col(&self) -> &[i32] {
        &*self.player_dir
    }
    #[inline]
    fn pocket_col(&self) -> &[i32] {
        &*self.pocket
    }
    #[inline]
    fn mission_col(&self) -> &[i32] {
        &*self.mission
    }
    #[inline]
    fn mission_tokens_col(&self) -> &[i32] {
        &*self.mission_tokens
    }
    #[inline]
    fn events_col(&self) -> &[Events] {
        &*self.events
    }
    #[inline]
    fn active_agent(&self) -> usize {
        self.agent
    }
    #[inline]
    fn grid_h(&self) -> usize {
        self.h
    }
    #[inline]
    fn grid_w(&self) -> usize {
        self.w
    }
}

macro_rules! shared_slot_api {
    ($T:ident) => {
        impl<'a> $T<'a> {
            #[inline]
            pub fn dims(&self) -> GridDims {
                GridDims::new(self.h, self.w)
            }

            /// Base cell type at `p` (out-of-bounds reads as Wall).
            #[inline]
            pub fn cell(&self, p: Pos) -> CellType {
                if !p.in_bounds(self.h, self.w) {
                    return CellType::Wall;
                }
                CellType::from_u8(self.base[(p.r as usize) * self.w + p.c as usize])
            }

            /// Colour of the base cell at `p`.
            #[inline]
            pub fn cell_color(&self, p: Pos) -> Color {
                if !p.in_bounds(self.h, self.w) {
                    return Color::Grey;
                }
                Color::from_u8(self.base_color[(p.r as usize) * self.w + p.c as usize])
            }

            /// Packed overlay code at `p`'s flat encoding, if it lands in
            /// the grid's code range. Mirrors the naive scans' index
            /// semantics *exactly*: `p.encode` is compared against the same
            /// flat range the entity tables store, so even the aliasing an
            /// out-of-bounds column produces (`r·W + c` with `c ≥ W` lands
            /// in the next row) resolves to the identical cell.
            #[inline]
            fn code_at_enc(&self, p: Pos) -> Option<(u32, usize)> {
                let enc = p.encode(self.w);
                if enc < 0 {
                    return None;
                }
                let i = enc as usize;
                if i >= self.overlay.len() {
                    return None;
                }
                Some((self.overlay[i], i))
            }

            /// Index of the door at `p`, if any. O(1) overlay read.
            #[inline]
            pub fn door_at(&self, p: Pos) -> Option<usize> {
                match self.code_at_enc(p) {
                    Some((code, i)) if cellcode::tag(code) == Tag::DOOR => {
                        Some(self.overlay_idx[i] as usize)
                    }
                    _ => None,
                }
            }

            /// Index of the (still on-ground) key at `p`, if any. O(1).
            #[inline]
            pub fn key_at(&self, p: Pos) -> Option<usize> {
                match self.code_at_enc(p) {
                    Some((code, i)) if cellcode::tag(code) == Tag::KEY => {
                        Some(self.overlay_idx[i] as usize)
                    }
                    _ => None,
                }
            }

            /// Index of the ball at `p`, if any. O(1).
            #[inline]
            pub fn ball_at(&self, p: Pos) -> Option<usize> {
                match self.code_at_enc(p) {
                    Some((code, i)) if cellcode::tag(code) == Tag::BALL => {
                        Some(self.overlay_idx[i] as usize)
                    }
                    _ => None,
                }
            }

            /// Index of the box at `p`, if any. O(1).
            #[inline]
            pub fn box_at(&self, p: Pos) -> Option<usize> {
                match self.code_at_enc(p) {
                    Some((code, i)) if cellcode::tag(code) == Tag::BOX => {
                        Some(self.overlay_idx[i] as usize)
                    }
                    _ => None,
                }
            }

            /// Is any dynamic entity occupying `p` (doors count regardless of
            /// open/closed; keys/balls/boxes only while on the ground)? O(1).
            #[inline]
            pub fn occupied_by_entity(&self, p: Pos) -> bool {
                match self.code_at_enc(p) {
                    Some((code, _)) => matches!(
                        cellcode::tag(code),
                        Tag::DOOR | Tag::KEY | Tag::BALL | Tag::BOX
                    ),
                    None => false,
                }
            }

            /// Can the agent walk onto `p`? (MiniGrid `can_overlap` rules:
            /// floor/goal/lava yes, wall no; open door yes, closed/locked no;
            /// key/ball/box on the ground block movement. A door *replaces*
            /// its cell, so its state decides regardless of the base cell —
            /// doors set into walls, e.g. GoToDoor's border doors, behave
            /// like MiniGrid's.) O(1) overlay read.
            #[inline]
            pub fn walkable(&self, p: Pos) -> bool {
                if !p.in_bounds(self.h, self.w) {
                    return false;
                }
                let code = self.overlay[(p.r as usize) * self.w + p.c as usize];
                match cellcode::tag(code) {
                    Tag::DOOR => cellcode::state(code) == DoorState::Open as i32,
                    Tag::WALL | Tag::KEY | Tag::BALL | Tag::BOX => false,
                    _ => true,
                }
            }

            /// Does `p` block line of sight? (walls, closed/locked doors;
            /// a door's state overrides the base cell it replaced) O(1).
            #[inline]
            pub fn opaque(&self, p: Pos) -> bool {
                match self.code_at_enc(p) {
                    Some((code, _)) => match cellcode::tag(code) {
                        Tag::DOOR => cellcode::state(code) != DoorState::Open as i32,
                        Tag::WALL => true,
                        // An aliased out-of-bounds `p` reads a real cell's
                        // code, but its *base* cell reads as Wall — exactly
                        // what the scan path falls back to.
                        _ => !p.in_bounds(self.h, self.w),
                    },
                    None => true,
                }
            }

            /// Is `p` free for entity placement (floor, nothing on it)? O(1).
            #[inline]
            pub fn free_for_placement(&self, p: Pos, player: Pos) -> bool {
                if !p.in_bounds(self.h, self.w) || p == player {
                    return false;
                }
                let code = self.overlay[(p.r as usize) * self.w + p.c as usize];
                cellcode::tag(code) == Tag::EMPTY
            }

            // ---- Naive first-match scans: the bitwise-parity oracle. ----
            //
            // These are the original O(caps) implementations. They stay in
            // the build because (a) the write-through setters use them to
            // recompute a mutated cell, and (b) `tests/test_obs_parity.rs`
            // and `benches/obs_throughput.rs` pin the overlay path against
            // them, state by state and output by output.

            /// Scan-path oracle for [`Self::door_at`].
            #[inline]
            pub fn door_at_scan(&self, p: Pos) -> Option<usize> {
                let enc = p.encode(self.w);
                if enc < 0 {
                    return None;
                }
                self.door_pos.iter().position(|&d| d == enc)
            }

            /// Scan-path oracle for [`Self::key_at`].
            #[inline]
            pub fn key_at_scan(&self, p: Pos) -> Option<usize> {
                let enc = p.encode(self.w);
                if enc < 0 {
                    return None;
                }
                self.key_pos.iter().position(|&k| k == enc && k >= 0)
            }

            /// Scan-path oracle for [`Self::ball_at`].
            #[inline]
            pub fn ball_at_scan(&self, p: Pos) -> Option<usize> {
                let enc = p.encode(self.w);
                if enc < 0 {
                    return None;
                }
                self.ball_pos.iter().position(|&x| x == enc && x >= 0)
            }

            /// Scan-path oracle for [`Self::box_at`].
            #[inline]
            pub fn box_at_scan(&self, p: Pos) -> Option<usize> {
                let enc = p.encode(self.w);
                if enc < 0 {
                    return None;
                }
                self.box_pos.iter().position(|&x| x == enc && x >= 0)
            }

            /// Scan-path oracle for [`Self::occupied_by_entity`].
            #[inline]
            pub fn occupied_by_entity_scan(&self, p: Pos) -> bool {
                self.door_at_scan(p).is_some()
                    || self.key_at_scan(p).is_some()
                    || self.ball_at_scan(p).is_some()
                    || self.box_at_scan(p).is_some()
            }

            /// Scan-path oracle for [`Self::walkable`].
            #[inline]
            pub fn walkable_scan(&self, p: Pos) -> bool {
                if !p.in_bounds(self.h, self.w) {
                    return false;
                }
                if let Some(d) = self.door_at_scan(p) {
                    return DoorState::from_u8(self.door_state[d]) == DoorState::Open;
                }
                if !self.cell(p).walkable() {
                    return false;
                }
                !(self.key_at_scan(p).is_some()
                    || self.ball_at_scan(p).is_some()
                    || self.box_at_scan(p).is_some())
            }

            /// Scan-path oracle for [`Self::opaque`].
            #[inline]
            pub fn opaque_scan(&self, p: Pos) -> bool {
                if let Some(d) = self.door_at_scan(p) {
                    return DoorState::from_u8(self.door_state[d]) != DoorState::Open;
                }
                !self.cell(p).transparent()
            }

            /// Scan-path oracle for [`Self::free_for_placement`].
            #[inline]
            pub fn free_for_placement_scan(&self, p: Pos, player: Pos) -> bool {
                self.cell(p) == CellType::Floor
                    && !self.occupied_by_entity_scan(p)
                    && p != player
            }

            /// Player position decoded.
            #[inline]
            pub fn player(&self) -> Pos {
                Pos::decode(self.player_pos_value(), self.w)
            }

            /// Player facing decoded.
            #[inline]
            pub fn dir(&self) -> Direction {
                Direction::from_i32(self.player_dir_value())
            }

            /// The cell directly in front of the player.
            #[inline]
            pub fn front(&self) -> Pos {
                self.player().step(self.dir())
            }

            /// Pocket decoded.
            #[inline]
            pub fn pocket_value(&self) -> Pocket {
                Pocket(self.pocket_raw())
            }

            /// Mission decoded (the typed goal-conditioning component; the
            /// single authority over the packed `mission` i32 — never
            /// decode the raw field by hand).
            #[inline]
            pub fn mission_value(&self) -> Mission {
                Mission::from_raw(self.mission_raw())
            }
        }
    };
}

shared_slot_api!(EnvSlot);
shared_slot_api!(SlotMut);

impl<'a> SlotMut<'a> {
    /// Sequential RNG stream over this env's per-env key state.
    #[inline]
    pub fn rng(&mut self) -> SlotRng<'_, 'a> {
        SlotRng { slot: self }
    }

    /// Recompute the overlay code + index channel of one in-bounds cell
    /// from the entity tables and base grid, using the same first-match
    /// precedence (door > key > ball > box > base) the scan oracle applies.
    /// O(caps) — paid once per mutation instead of per cell per step.
    pub fn recompute_cell(&mut self, p: Pos) {
        debug_assert!(p.in_bounds(self.h, self.w));
        let i = (p.r as usize) * self.w + p.c as usize;
        let (code, idx) = if let Some(d) = self.door_at_scan(p) {
            (cellcode::pack(Tag::DOOR, self.door_color[d], self.door_state[d]), d as u8)
        } else if let Some(k) = self.key_at_scan(p) {
            (cellcode::pack(Tag::KEY, self.key_color[k], 0), k as u8)
        } else if let Some(b) = self.ball_at_scan(p) {
            (cellcode::pack(Tag::BALL, self.ball_color[b], 0), b as u8)
        } else if let Some(b) = self.box_at_scan(p) {
            (cellcode::pack(Tag::BOX, self.box_color[b], 0), b as u8)
        } else {
            (cellcode::base_code(self.cell(p), self.base_color[i]), cellcode::NONE_IDX)
        };
        self.overlay[i] = code;
        self.overlay_idx[i] = idx;
    }

    /// Rebuild the whole overlay from the base grid + entity tables
    /// (O(H·W + caps)): base codes first, then entities splatted in reverse
    /// precedence (and reverse index order within a kind) so the result is
    /// identical to per-cell first-match recomputation.
    pub fn rebuild_overlay(&mut self) {
        let hw = self.h * self.w;
        for i in 0..hw {
            self.overlay[i] =
                cellcode::base_code(CellType::from_u8(self.base[i]), self.base_color[i]);
            self.overlay_idx[i] = cellcode::NONE_IDX;
        }
        for x in (0..self.box_pos.len()).rev() {
            let enc = self.box_pos[x];
            if enc >= 0 && (enc as usize) < hw {
                self.overlay[enc as usize] = cellcode::pack(Tag::BOX, self.box_color[x], 0);
                self.overlay_idx[enc as usize] = x as u8;
            }
        }
        for x in (0..self.ball_pos.len()).rev() {
            let enc = self.ball_pos[x];
            if enc >= 0 && (enc as usize) < hw {
                self.overlay[enc as usize] = cellcode::pack(Tag::BALL, self.ball_color[x], 0);
                self.overlay_idx[enc as usize] = x as u8;
            }
        }
        for x in (0..self.key_pos.len()).rev() {
            let enc = self.key_pos[x];
            if enc >= 0 && (enc as usize) < hw {
                self.overlay[enc as usize] = cellcode::pack(Tag::KEY, self.key_color[x], 0);
                self.overlay_idx[enc as usize] = x as u8;
            }
        }
        for x in (0..self.door_pos.len()).rev() {
            let enc = self.door_pos[x];
            if enc >= 0 && (enc as usize) < hw {
                self.overlay[enc as usize] =
                    cellcode::pack(Tag::DOOR, self.door_color[x], self.door_state[x]);
                self.overlay_idx[enc as usize] = x as u8;
            }
        }
    }

    /// Set the base cell type (+ colour) at `p` (write-through).
    #[inline]
    pub fn set_cell(&mut self, p: Pos, t: CellType, color: Color) {
        debug_assert!(p.in_bounds(self.h, self.w));
        let idx = (p.r as usize) * self.w + p.c as usize;
        self.base[idx] = t as u8;
        self.base_color[idx] = color as u8;
        self.recompute_cell(p);
    }

    /// Fill the whole base grid with floor surrounded by a wall ring.
    pub fn fill_room(&mut self) {
        let (h, w) = (self.h, self.w);
        for r in 0..h {
            for c in 0..w {
                let border = r == 0 || c == 0 || r == h - 1 || c == w - 1;
                let idx = r * w + c;
                self.base[idx] = if border { CellType::Wall } else { CellType::Floor } as u8;
                self.base_color[idx] = Color::Grey as u8;
            }
        }
        self.rebuild_overlay();
    }

    /// Clear all dynamic entities and bookkeeping (used before layout).
    /// Extra agents (rows ≥ 1) are unplaced here and re-placed by the
    /// reset path after the generator ran; agent 0's stale position is
    /// left alone exactly like the single-agent path always did (the
    /// generator's `place_player` overwrites it).
    pub fn clear_entities(&mut self) {
        self.door_pos.fill(-1);
        self.key_pos.fill(-1);
        self.ball_pos.fill(-1);
        self.box_pos.fill(-1);
        self.pocket.fill(-1);
        self.mission.fill(Mission::NONE.raw());
        self.mission_tokens.fill(0);
        self.events.fill(Events::NONE);
        self.last_action.fill(-1);
        for j in 1..self.player_pos.len() {
            self.player_pos[j] = -1;
            self.player_dir[j] = 0;
        }
        *self.t = 0;
        self.rebuild_overlay();
    }

    /// Place the active agent. (Agents are not part of the overlay — the
    /// observation writers overlay them — so no recompute is needed.)
    #[inline]
    pub fn place_player(&mut self, p: Pos, dir: Direction) {
        let j = self.agent;
        self.player_pos[j] = p.encode(self.w);
        self.player_dir[j] = dir as i32;
    }

    /// Place agent `j` of this slot (the multi-agent reset path).
    #[inline]
    pub fn place_agent(&mut self, j: usize, p: Pos, dir: Direction) {
        self.player_pos[j] = p.encode(self.w);
        self.player_dir[j] = dir as i32;
    }

    /// Set the slot's mission for every agent (missions are shared by the
    /// whole team; per-agent rows exist so evaluation stays row-local).
    /// Writes both the packed clause column and the token slab via the
    /// lossless 1-clause embedding, so legacy generators produce
    /// grammar-correct state unchanged.
    #[inline]
    pub fn set_mission(&mut self, m: Mission) {
        self.set_mission_spec(MissionSpec::from_mission(m));
    }

    /// Set the slot's compositional mission for every agent: the token
    /// slab gets the serialised spec, the packed `mission` column the
    /// active clause.
    pub fn set_mission_spec(&mut self, spec: MissionSpec) {
        self.mission.fill(spec.active_mission().raw());
        let a = self.mission.len();
        for j in 0..a {
            spec.write_tokens(&mut self.mission_tokens[j * MISSION_TOKENS..(j + 1) * MISSION_TOKENS]);
        }
    }

    /// Latch the active agent's current clause complete, advancing the
    /// cursor: rewrites that agent's token row and packed mission column.
    /// Returns `true` when this completed the whole mission.
    pub fn advance_mission_clause(&mut self) -> bool {
        let j = self.agent;
        let row = &mut self.mission_tokens[j * MISSION_TOKENS..(j + 1) * MISSION_TOKENS];
        let mut spec = MissionSpec::from_tokens(row);
        if spec.is_empty() {
            // A mission poked straight into the packed column (legacy
            // tests/tools) has no slab row: treat it as its 1-clause
            // embedding so completion semantics still hold.
            spec = MissionSpec::from_mission(Mission::from_raw(self.mission[j]));
            if spec.is_empty() {
                return false;
            }
        }
        let completed = spec.mark_active_done();
        spec.write_tokens(row);
        self.mission[j] = spec.active_mission().raw();
        completed
    }

    /// Add a door at `p`. Panics if capacity is exhausted (a config bug).
    pub fn add_door(&mut self, p: Pos, color: Color, state: DoorState) -> usize {
        // The overlay stores one entity per cell (door > key > ball > box):
        // a second entity under a door would be silently hidden from the
        // O(1) queries, so enforce the invariant at the write.
        debug_assert!(
            self.key_at_scan(p).is_none()
                && self.ball_at_scan(p).is_none()
                && self.box_at_scan(p).is_none(),
            "overlay invariant: a door may not be placed over another entity at {p:?}"
        );
        let slot = self
            .door_pos
            .iter()
            .position(|&d| d < 0)
            .expect("door capacity exhausted: bump Caps.doors in the env config");
        self.door_pos[slot] = p.encode(self.w);
        self.door_color[slot] = color as u8;
        self.door_state[slot] = state as u8;
        self.recompute_cell(p);
        slot
    }

    /// Add a key at `p` if a table slot is free (the runtime `drop` path).
    pub fn try_add_key(&mut self, p: Pos, color: Color) -> Option<usize> {
        debug_assert!(
            !self.occupied_by_entity_scan(p),
            "overlay invariant: one entity per cell (key onto occupied {p:?})"
        );
        let slot = self.key_pos.iter().position(|&k| k < 0)?;
        self.key_pos[slot] = p.encode(self.w);
        self.key_color[slot] = color as u8;
        self.recompute_cell(p);
        Some(slot)
    }

    /// Add a key at `p`. Panics if capacity is exhausted (a config bug).
    pub fn add_key(&mut self, p: Pos, color: Color) -> usize {
        self.try_add_key(p, color)
            .expect("key capacity exhausted: bump Caps.keys in the env config")
    }

    /// Add a ball at `p` if a table slot is free (the runtime `drop` path).
    pub fn try_add_ball(&mut self, p: Pos, color: Color) -> Option<usize> {
        debug_assert!(
            !self.occupied_by_entity_scan(p),
            "overlay invariant: one entity per cell (ball onto occupied {p:?})"
        );
        let slot = self.ball_pos.iter().position(|&x| x < 0)?;
        self.ball_pos[slot] = p.encode(self.w);
        self.ball_color[slot] = color as u8;
        self.recompute_cell(p);
        Some(slot)
    }

    /// Add a ball at `p`. Panics if capacity is exhausted (a config bug).
    pub fn add_ball(&mut self, p: Pos, color: Color) -> usize {
        self.try_add_ball(p, color)
            .expect("ball capacity exhausted: bump Caps.balls in the env config")
    }

    /// Add a box at `p` if a table slot is free (the runtime `drop` path).
    pub fn try_add_box(&mut self, p: Pos, color: Color) -> Option<usize> {
        debug_assert!(
            !self.occupied_by_entity_scan(p),
            "overlay invariant: one entity per cell (box onto occupied {p:?})"
        );
        let slot = self.box_pos.iter().position(|&x| x < 0)?;
        self.box_pos[slot] = p.encode(self.w);
        self.box_color[slot] = color as u8;
        self.recompute_cell(p);
        Some(slot)
    }

    /// Add a box at `p`. Panics if capacity is exhausted (a config bug).
    pub fn add_box(&mut self, p: Pos, color: Color) -> usize {
        self.try_add_box(p, color)
            .expect("box capacity exhausted: bump Caps.boxes in the env config")
    }

    /// Set door `d`'s open/closed/locked state (write-through).
    #[inline]
    pub fn set_door_state(&mut self, d: usize, state: DoorState) {
        self.door_state[d] = state as u8;
        let enc = self.door_pos[d];
        if enc >= 0 {
            self.recompute_cell(Pos::decode(enc, self.w));
        }
    }

    /// Take key `k` off the grid (pickup: position −1, write-through).
    #[inline]
    pub fn remove_key(&mut self, k: usize) {
        let enc = self.key_pos[k];
        self.key_pos[k] = -1;
        if enc >= 0 {
            self.recompute_cell(Pos::decode(enc, self.w));
        }
    }

    /// Take ball `b` off the grid (pickup: position −1, write-through).
    #[inline]
    pub fn remove_ball(&mut self, b: usize) {
        let enc = self.ball_pos[b];
        self.ball_pos[b] = -1;
        if enc >= 0 {
            self.recompute_cell(Pos::decode(enc, self.w));
        }
    }

    /// Take box `b` off the grid (pickup: position −1, write-through).
    #[inline]
    pub fn remove_box(&mut self, b: usize) {
        let enc = self.box_pos[b];
        self.box_pos[b] = -1;
        if enc >= 0 {
            self.recompute_cell(Pos::decode(enc, self.w));
        }
    }

    /// Move ball `b` to `q` (Dynamic-Obstacles drift, write-through: both
    /// the vacated and the entered cell are recomputed).
    #[inline]
    pub fn move_ball(&mut self, b: usize, q: Pos) {
        debug_assert!(q.in_bounds(self.h, self.w));
        debug_assert!(
            self.ball_pos[b] == q.encode(self.w) || !self.occupied_by_entity_scan(q),
            "overlay invariant: one entity per cell (ball onto occupied {q:?})"
        );
        let old = self.ball_pos[b];
        self.ball_pos[b] = q.encode(self.w);
        if old >= 0 {
            self.recompute_cell(Pos::decode(old, self.w));
        }
        self.recompute_cell(q);
    }

    /// Sample a uniformly random free interior floor cell (rejection
    /// sampling, like MiniGrid's `place_obj`). Errors instead of panicking
    /// when the grid has no free cell left — crowded or degenerate layouts
    /// are a recoverable condition for the reset path, not a crash.
    pub fn sample_free_cell(&mut self, avoid_player: bool) -> Result<Pos, PlacementError> {
        let (h, w) = (self.h as i32, self.w as i32);
        self.sample_free_in(1, 1, h - 1, w - 1, avoid_player)
    }

    /// Sample a uniformly random free floor cell within rows `[r0, r1)` ×
    /// cols `[c0, c1)` (the rectangle primitive the RoomGrid builders use).
    /// Rejection sampling first; if the rectangle is crowded, a
    /// deterministic wrap-around sweep whose start is RNG-derived takes
    /// over, so placement is not biased toward the top-left corner.
    pub fn sample_free_in(
        &mut self,
        r0: i32,
        c0: i32,
        r1: i32,
        c1: i32,
        avoid_player: bool,
    ) -> Result<Pos, PlacementError> {
        let err = PlacementError { h: self.h, w: self.w, r0, c0, r1, c1 };
        let rows = r1 - r0;
        let cols = c1 - c0;
        if rows <= 0 || cols <= 0 {
            return Err(err);
        }
        // `agent_at` probes every agent of the slot, so multi-agent resets
        // never stack agents; with one agent this is exactly the old
        // `p != player` check (and an unplaced agent, position −1, never
        // matches — same as the old decode of −1).
        let free = |s: &Self, p: Pos| {
            s.cell(p) == CellType::Floor
                && !s.occupied_by_entity(p)
                && (!avoid_player || s.agent_at(p).is_none())
        };
        for _ in 0..256 {
            let (r, c) = {
                let mut rng = self.rng();
                (rng.randint(r0, r1), rng.randint(c0, c1))
            };
            let p = Pos::new(r, c);
            if free(self, p) {
                return Ok(p);
            }
        }
        let n = (rows as u32) * (cols as u32);
        let start = {
            let mut rng = self.rng();
            rng.below(n)
        };
        for k in 0..n {
            let idx = (start + k) % n;
            let p = Pos::new(r0 + (idx / cols as u32) as i32, c0 + (idx % cols as u32) as i32);
            if free(self, p) {
                return Ok(p);
            }
        }
        Err(err)
    }
}

/// No free cell exists in the sampled region. Layout generators surface this
/// (the env id is attached by [`crate::envs::EnvConfig::reset_slot`]) so the
/// reset path can retry or report instead of panicking mid-batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementError {
    /// Grid dimensions.
    pub h: usize,
    pub w: usize,
    /// The scanned rectangle, rows `[r0, r1)` × cols `[c0, c1)`.
    pub r0: i32,
    pub c0: i32,
    pub r1: i32,
    pub c1: i32,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "no free cell in rows {}..{} × cols {}..{} of a {}×{} grid",
            self.r0, self.r1, self.c0, self.c1, self.h, self.w
        )
    }
}

impl std::error::Error for PlacementError {}

/// A short-lived RNG stream advancing the slot's per-env key state.
pub struct SlotRng<'s, 'a> {
    slot: &'s mut SlotMut<'a>,
}

impl SlotRng<'_, '_> {
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut r = Rng { state: *self.slot.rng };
        let x = r.next_u64();
        *self.slot.rng = r.state;
        x
    }

    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        (((self.next_u64() >> 32) * n as u64) >> 32) as u32
    }

    #[inline]
    pub fn randint(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.below((hi - lo) as u32) as i32
    }

    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        ((self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_state() -> BatchedState {
        BatchedState::new(2, 5, 6, Caps { doors: 2, keys: 2, balls: 2, boxes: 1 })
    }

    #[test]
    fn allocation_shapes() {
        let st = small_state();
        assert_eq!(st.base.len(), 2 * 5 * 6);
        assert_eq!(st.door_pos.len(), 4);
        assert_eq!(st.key_pos.len(), 4);
        assert_eq!(st.player_pos.len(), 2);
    }

    #[test]
    fn fill_room_builds_wall_ring() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        assert_eq!(s.cell(Pos::new(0, 0)), CellType::Wall);
        assert_eq!(s.cell(Pos::new(4, 5)), CellType::Wall);
        assert_eq!(s.cell(Pos::new(2, 2)), CellType::Floor);
        // env 1 untouched (still all wall from init)
        let s1 = st.slot(1);
        assert_eq!(s1.cell(Pos::new(2, 2)), CellType::Wall);
    }

    #[test]
    fn entity_placement_and_lookup() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        let d = s.add_door(Pos::new(2, 3), Color::Yellow, DoorState::Locked);
        s.add_key(Pos::new(1, 2), Color::Yellow);
        assert_eq!(s.door_at(Pos::new(2, 3)), Some(d));
        assert_eq!(s.key_at(Pos::new(1, 2)), Some(0));
        assert!(s.occupied_by_entity(Pos::new(2, 3)));
        assert!(!s.walkable(Pos::new(2, 3))); // locked door
        assert!(!s.walkable(Pos::new(1, 2))); // key blocks
        assert!(s.walkable(Pos::new(3, 3)));
        assert!(s.opaque(Pos::new(2, 3))); // locked door blocks sight
        s.set_door_state(d, DoorState::Open);
        assert!(s.walkable(Pos::new(2, 3)));
        assert!(!s.opaque(Pos::new(2, 3)));
    }

    /// Exhaustive fast-vs-scan agreement over every cell of a slot.
    fn assert_overlay_consistent(s: &EnvSlot<'_>) {
        for r in 0..s.h as i32 {
            for c in 0..s.w as i32 {
                let p = Pos::new(r, c);
                let i = (r as usize) * s.w + c as usize;
                let code = s.overlay[i];
                let expect = if let Some(d) = s.door_at_scan(p) {
                    cellcode::pack(Tag::DOOR, s.door_color[d], s.door_state[d])
                } else if let Some(k) = s.key_at_scan(p) {
                    cellcode::pack(Tag::KEY, s.key_color[k], 0)
                } else if let Some(b) = s.ball_at_scan(p) {
                    cellcode::pack(Tag::BALL, s.ball_color[b], 0)
                } else if let Some(b) = s.box_at_scan(p) {
                    cellcode::pack(Tag::BOX, s.box_color[b], 0)
                } else {
                    cellcode::base_code(s.cell(p), s.base_color[i])
                };
                assert_eq!(code, expect, "overlay desync at {p:?}");
                assert_eq!(s.door_at(p), s.door_at_scan(p), "door_at at {p:?}");
                assert_eq!(s.key_at(p), s.key_at_scan(p), "key_at at {p:?}");
                assert_eq!(s.ball_at(p), s.ball_at_scan(p), "ball_at at {p:?}");
                assert_eq!(s.box_at(p), s.box_at_scan(p), "box_at at {p:?}");
                assert_eq!(s.walkable(p), s.walkable_scan(p), "walkable at {p:?}");
                assert_eq!(s.opaque(p), s.opaque_scan(p), "opaque at {p:?}");
                assert_eq!(
                    s.occupied_by_entity(p),
                    s.occupied_by_entity_scan(p),
                    "occupied at {p:?}"
                );
                let player = s.player();
                assert_eq!(
                    s.free_for_placement(p, player),
                    s.free_for_placement_scan(p, player),
                    "free_for_placement at {p:?}"
                );
            }
        }
    }

    #[test]
    fn overlay_stays_consistent_through_every_setter() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        let d = s.add_door(Pos::new(2, 3), Color::Yellow, DoorState::Locked);
        let k = s.add_key(Pos::new(1, 2), Color::Yellow);
        let b = s.add_ball(Pos::new(3, 2), Color::Blue);
        s.add_box(Pos::new(3, 4), Color::Green);
        s.set_cell(Pos::new(2, 2), CellType::Goal, Color::Green);
        s.set_cell(Pos::new(1, 4), CellType::Lava, Color::Red);
        drop(s);
        assert_overlay_consistent(&st.slot(0));

        let mut s = st.slot_mut(0);
        s.set_door_state(d, DoorState::Open);
        s.remove_key(k);
        s.move_ball(b, Pos::new(2, 4));
        drop(s);
        assert_overlay_consistent(&st.slot(0));

        let mut s = st.slot_mut(0);
        s.remove_ball(b);
        s.remove_box(0);
        s.try_add_key(Pos::new(3, 3), Color::Red).unwrap();
        s.set_door_state(d, DoorState::Closed);
        drop(s);
        assert_overlay_consistent(&st.slot(0));

        let mut s = st.slot_mut(0);
        s.clear_entities();
        drop(s);
        assert_overlay_consistent(&st.slot(0));
    }

    #[test]
    fn overlay_codes_premerge_base_and_entities() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.set_cell(Pos::new(2, 2), CellType::Goal, Color::Green);
        s.add_key(Pos::new(1, 2), Color::Yellow);
        let at = |s: &SlotMut<'_>, r: usize, c: usize| s.overlay[r * 6 + c];
        assert_eq!(cellcode::tag(at(&s, 0, 0)), Tag::WALL);
        assert_eq!(cellcode::color(at(&s, 0, 0)), Color::Grey as i32);
        assert_eq!(cellcode::tag(at(&s, 2, 2)), Tag::GOAL);
        assert_eq!(cellcode::color(at(&s, 2, 2)), 1);
        assert_eq!(cellcode::tag(at(&s, 1, 2)), Tag::KEY);
        assert_eq!(cellcode::color(at(&s, 1, 2)), Color::Yellow as i32);
        assert_eq!(s.overlay_idx[1 * 6 + 2], 0);
        assert_eq!(cellcode::tag(at(&s, 3, 3)), Tag::EMPTY);
        assert_eq!(s.overlay_idx[3 * 6 + 3], cellcode::NONE_IDX);
        // A door replacing a wall keeps door precedence in the merged code.
        let d = s.add_door(Pos::new(2, 3), Color::Red, DoorState::Locked);
        assert_eq!(cellcode::tag(at(&s, 2, 3)), Tag::DOOR);
        assert_eq!(cellcode::state(at(&s, 2, 3)), DoorState::Locked as i32);
        assert_eq!(s.overlay_idx[2 * 6 + 3], d as u8);
    }

    #[test]
    fn out_of_bounds_reads_as_wall() {
        let st = small_state();
        let s = st.slot(0);
        assert_eq!(s.cell(Pos::new(-1, 0)), CellType::Wall);
        assert_eq!(s.cell(Pos::new(0, 99)), CellType::Wall);
        assert!(!s.walkable(Pos::new(-1, -1)));
    }

    #[test]
    fn front_cell_tracks_direction() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(2, 2), Direction::North);
        assert_eq!(s.front(), Pos::new(1, 2));
        s.player_dir[0] = Direction::South as i32;
        assert_eq!(s.front(), Pos::new(3, 2));
    }

    #[test]
    fn sample_free_cell_avoids_entities_and_player() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        *s.rng = 123;
        s.place_player(Pos::new(1, 1), Direction::East);
        s.add_key(Pos::new(1, 2), Color::Red);
        for _ in 0..50 {
            let p = s.sample_free_cell(true).expect("room has free cells");
            assert_ne!(p, Pos::new(1, 1));
            assert_ne!(p, Pos::new(1, 2));
            assert_eq!(s.cell(p), CellType::Floor);
        }
    }

    #[test]
    fn sample_free_in_respects_rectangle() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        *s.rng = 9;
        for _ in 0..50 {
            let p = s.sample_free_in(2, 3, 4, 5, false).unwrap();
            assert!(p.r >= 2 && p.r < 4 && p.c >= 3 && p.c < 5, "{p:?} outside rect");
        }
    }

    #[test]
    fn crowded_grid_returns_error_not_panic() {
        // Fill every interior cell with keys: no free cell remains.
        let mut st = BatchedState::new(1, 4, 4, Caps { keys: 4, ..Caps::default() });
        let mut s = st.slot_mut(0);
        s.fill_room();
        *s.rng = 5;
        for p in [Pos::new(1, 1), Pos::new(1, 2), Pos::new(2, 1), Pos::new(2, 2)] {
            s.add_key(p, Color::Red);
        }
        let err = s.sample_free_cell(false).unwrap_err();
        assert_eq!((err.h, err.w), (4, 4));
        let msg = format!("{err}");
        assert!(msg.contains("4×4"), "error must carry grid dims: {msg}");
        // Degenerate rectangle is an error too, not a debug_assert crash.
        assert!(s.sample_free_in(2, 2, 2, 2, false).is_err());
    }

    #[test]
    fn crowded_fallback_sweep_is_not_corner_biased() {
        // One free cell left; the sweep must find it regardless of where it
        // is, and different RNG states must still all find it (the offset
        // only rotates the scan order).
        for free in [Pos::new(1, 1), Pos::new(2, 3), Pos::new(3, 4)] {
            let mut st = BatchedState::new(1, 5, 6, Caps { keys: 12, ..Caps::default() });
            let mut s = st.slot_mut(0);
            s.fill_room();
            *s.rng = 1234;
            for p in s.dims().interior().collect::<Vec<_>>() {
                if p != free {
                    s.add_key(p, Color::Blue);
                }
            }
            assert_eq!(s.sample_free_cell(false).unwrap(), free);
        }
    }

    #[test]
    fn clear_entities_resets() {
        let mut st = small_state();
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.add_door(Pos::new(2, 3), Color::Red, DoorState::Closed);
        *s.t = 42;
        s.clear_entities();
        assert!(s.door_pos.iter().all(|&d| d < 0));
        assert_eq!(*s.t, 0);
    }

    #[test]
    fn agent_axis_columns_and_views() {
        let mut st = BatchedState::with_agents(2, 5, 6, Caps::default(), 3);
        assert_eq!(st.player_pos.len(), 6);
        assert_eq!(st.events.len(), 6);
        assert_eq!(st.t.len(), 2, "episode clock stays per slot");
        {
            let mut s = st.agent_slot_mut(1, 2);
            s.fill_room();
            s.place_player(Pos::new(2, 2), Direction::North);
        }
        let s = st.agent_slot(1, 2);
        assert_eq!(s.agent_count(), 3);
        assert_eq!(s.player(), Pos::new(2, 2));
        assert_eq!(s.agent_at(Pos::new(2, 2)), Some(2));
        assert_eq!(s.other_agent_at(Pos::new(2, 2)), None, "self is not an obstacle");
        let s0 = st.agent_slot(1, 0);
        assert_eq!(s0.other_agent_at(Pos::new(2, 2)), Some(2));
        // Out-of-bounds columns must not alias onto a placed agent's row.
        assert_eq!(s0.agent_at(Pos::new(1, 8)), None);
        // Slot 0 is untouched.
        assert_eq!(st.slot(0).player_pos_value(), -1);
    }

    #[test]
    fn sampling_avoids_every_agent() {
        let mut st = BatchedState::with_agents(1, 5, 6, Caps::default(), 2);
        let mut s = st.agent_slot_mut(0, 0);
        s.fill_room();
        *s.rng = 77;
        s.place_player(Pos::new(1, 1), Direction::East);
        s.place_agent(1, Pos::new(2, 2), Direction::West);
        for _ in 0..50 {
            let p = s.sample_free_cell(true).expect("room has free cells");
            assert_ne!(p, Pos::new(1, 1));
            assert_ne!(p, Pos::new(2, 2));
        }
    }

    #[test]
    fn clear_entities_unplaces_extra_agents_only() {
        let mut st = BatchedState::with_agents(1, 5, 6, Caps::default(), 2);
        let mut s = st.agent_slot_mut(0, 0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        s.place_agent(1, Pos::new(2, 2), Direction::South);
        s.clear_entities();
        assert_eq!(s.player_pos[0], Pos::new(1, 1).encode(6), "agent 0 keeps its stale pos");
        assert_eq!(s.player_pos[1], -1, "extra agents are unplaced");
        assert_eq!(s.player_dir[1], 0);
    }
}
