//! Core data model of the NAVIX Entity-Component-System engine.
//!
//! The paper (§3.1, Tables 1–3) structures the environment as *entities*
//! (Player, Wall, Goal, Key, Door, Lava, Ball, Box) composed of *components*
//! (Position, Direction, Colour, …), processed by *systems* (intervention,
//! transition, observation, reward, termination — see [`crate::systems`]).
//!
//! This module defines the grid substrate, the component/entity vocabulary,
//! the struct-of-arrays batched state (the `vmap` analog: every component is
//! a flat array over the batch, entity capacities are static per environment
//! configuration — exactly the static-shape constraint that makes the
//! original NAVIX jittable), and the paper's `Timestep` interface.

pub mod actions;
pub mod components;
pub mod entities;
pub mod events;
pub mod grid;
pub mod mission;
pub mod snapshot;
pub mod state;
pub mod timestep;

pub use actions::Action;
pub use components::{Color, DoorState, Direction};
pub use entities::{CellType, EntityKind};
pub use mission::{Mission, MissionVerb, MISSION_TOKENS};
pub use snapshot::{EngineCheckpoint, SlotCheckpoint, SlotSnapshot};
pub use state::{BatchedState, EnvSlot, SlotMut};
pub use timestep::{StepType, Timestep};
