//! ECSM entities (paper Table 2) and the cell-type vocabulary.
//!
//! Integer tags follow MiniGrid's `OBJECT_TO_IDX` exactly so that symbolic
//! observations are drop-in compatible:
//! `unseen=0, empty=1, wall=2, floor=3, door=4, key=5, ball=6, box=7, goal=8,
//! lava=9, agent=10`.

/// Static cell content of the *base grid* (things that never move during an
/// episode). Dynamic entities (player, doors, keys, balls, boxes) live in the
/// entity tables of [`crate::core::state::BatchedState`] and are overlaid at
/// observation/collision time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CellType {
    Floor = 0,
    Wall = 1,
    Goal = 2,
    Lava = 3,
}

impl CellType {
    #[inline]
    pub fn from_u8(x: u8) -> CellType {
        match x {
            0 => CellType::Floor,
            1 => CellType::Wall,
            2 => CellType::Goal,
            _ => CellType::Lava,
        }
    }

    /// Can the agent stand on this base cell (ignoring dynamic entities)?
    #[inline]
    pub fn walkable(self) -> bool {
        !matches!(self, CellType::Wall)
    }

    /// Does this base cell block line of sight?
    #[inline]
    pub fn transparent(self) -> bool {
        !matches!(self, CellType::Wall)
    }

    /// MiniGrid symbolic object index of the base cell.
    #[inline]
    pub fn tag(self) -> i32 {
        match self {
            CellType::Floor => Tag::EMPTY,
            CellType::Wall => Tag::WALL,
            CellType::Goal => Tag::GOAL,
            CellType::Lava => Tag::LAVA,
        }
    }
}

/// MiniGrid symbolic object indices.
pub struct Tag;

impl Tag {
    pub const UNSEEN: i32 = 0;
    pub const EMPTY: i32 = 1;
    pub const WALL: i32 = 2;
    pub const FLOOR: i32 = 3;
    pub const DOOR: i32 = 4;
    pub const KEY: i32 = 5;
    pub const BALL: i32 = 6;
    pub const BOX: i32 = 7;
    pub const GOAL: i32 = 8;
    pub const LAVA: i32 = 9;
    pub const AGENT: i32 = 10;
}

/// The entity kinds of paper Table 2. Used for inventory printing
/// (`navix info`), pocket encoding and pickup rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntityKind {
    Wall,
    Player,
    Goal,
    Key,
    Door,
    Lava,
    Ball,
    Box,
}

impl EntityKind {
    pub fn tag(self) -> i32 {
        match self {
            EntityKind::Wall => Tag::WALL,
            EntityKind::Player => Tag::AGENT,
            EntityKind::Goal => Tag::GOAL,
            EntityKind::Key => Tag::KEY,
            EntityKind::Door => Tag::DOOR,
            EntityKind::Lava => Tag::LAVA,
            EntityKind::Ball => Tag::BALL,
            EntityKind::Box => Tag::BOX,
        }
    }

    /// Can the agent pick this entity up (the `Pickable` component)?
    pub fn pickable(self) -> bool {
        matches!(self, EntityKind::Key | EntityKind::Ball | EntityKind::Box)
    }

    /// Components composing this entity, for the live Table-2 inventory.
    pub fn components(self) -> &'static [&'static str] {
        match self {
            EntityKind::Wall => &["Positionable", "HasTag", "HasSprite", "HasColour"],
            EntityKind::Player => {
                &["Positionable", "HasTag", "HasSprite", "Directional", "Holder"]
            }
            EntityKind::Goal => {
                &["Positionable", "HasTag", "HasSprite", "HasColour", "Stochastic"]
            }
            EntityKind::Key => &["Positionable", "HasTag", "HasSprite", "Pickable", "HasColour"],
            EntityKind::Door => &["Positionable", "HasTag", "HasSprite", "Openable", "HasColour"],
            EntityKind::Lava => &["Positionable", "HasTag", "HasSprite"],
            EntityKind::Ball => {
                &["Positionable", "HasTag", "HasSprite", "HasColour", "Stochastic"]
            }
            EntityKind::Box => &["Positionable", "HasTag", "HasSprite", "HasColour", "Holder"],
        }
    }

    pub const ALL: [EntityKind; 8] = [
        EntityKind::Wall,
        EntityKind::Player,
        EntityKind::Goal,
        EntityKind::Key,
        EntityKind::Door,
        EntityKind::Lava,
        EntityKind::Ball,
        EntityKind::Box,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_match_minigrid_object_to_idx() {
        assert_eq!(Tag::UNSEEN, 0);
        assert_eq!(Tag::WALL, 2);
        assert_eq!(Tag::DOOR, 4);
        assert_eq!(Tag::KEY, 5);
        assert_eq!(Tag::BALL, 6);
        assert_eq!(Tag::GOAL, 8);
        assert_eq!(Tag::LAVA, 9);
        assert_eq!(Tag::AGENT, 10);
    }

    #[test]
    fn walls_block_walk_and_sight() {
        assert!(!CellType::Wall.walkable());
        assert!(!CellType::Wall.transparent());
        assert!(CellType::Goal.walkable());
        assert!(CellType::Lava.walkable()); // walking into lava is how you die
    }

    #[test]
    fn pickable_entities() {
        assert!(EntityKind::Key.pickable());
        assert!(EntityKind::Ball.pickable());
        assert!(EntityKind::Box.pickable());
        assert!(!EntityKind::Door.pickable());
        assert!(!EntityKind::Goal.pickable());
    }

    #[test]
    fn all_entities_have_position_tag_sprite() {
        for e in EntityKind::ALL {
            let cs = e.components();
            assert!(cs.contains(&"Positionable"));
            assert!(cs.contains(&"HasTag"));
            assert!(cs.contains(&"HasSprite"));
        }
    }

    #[test]
    fn celltype_roundtrip() {
        for t in [CellType::Floor, CellType::Wall, CellType::Goal, CellType::Lava] {
            assert_eq!(CellType::from_u8(t as u8), t);
        }
    }
}
