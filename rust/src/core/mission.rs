//! The typed `Mission` component — goal conditioning as first-class state.
//!
//! NAVIX positions MiniGrid as a substrate for *language-conditioned* RL:
//! several families (GoToDoor, KeyCorridor, Fetch, Unlock/UnlockPickup, and
//! the BabyAI-style GoToObj/PutNext families) parameterise each episode with
//! a goal — "go to the red door", "pick up the blue key", "put the ball next
//! to the box". Before this module the goal lived in the batched state as a
//! bare `i32` poked by layout generators as `(tag << 8) | colour` and decoded
//! by hand in the intervention system; nothing ever *showed* it to the
//! policy, so every mission-conditioned env was unlearnable.
//!
//! [`Mission`] makes the encoding a single, typed authority:
//!
//! * **task verb** — what to do ([`MissionVerb`]: go to / pick up /
//!   put next to);
//! * **object kind × colour** — what to do it to;
//! * for `PutNext`, a **second object kind × colour** — what to put it
//!   next to.
//!
//! ## Bit layout (preserved from the legacy `(tag << 8) | colour` pokes)
//!
//! ```text
//! bit 0..8    target colour                 (Color as u8)
//! bit 8..16   target object kind            (MiniGrid Tag)
//! bit 16..18  verb code: 0 = kind default   (GoTo for Door, PickUp for
//!             pickables — the legacy implicit verb), 1 = explicit GoTo,
//!             2 = PutNext
//! bit 18..21  second object kind            (PutNext only; Tag fits 3 bits)
//! bit 21..24  second object colour          (PutNext only)
//! ```
//!
//! `-1` (all bits set, sign negative) means "no mission". Crucially, verb
//! code 0 resolves to the verb the legacy encoding implied, so every mission
//! value produced before this module ([`Mission::pick_up`],
//! [`Mission::go_to`] on a door) is **bit-identical** to the old ad-hoc
//! pokes — the shard-invariance and cross-engine parity pins carry over
//! untouched.
//!
//! ## The feature vector
//!
//! [`Mission::write_features`] renders the mission as a fixed-width
//! ([`MISSION_DIM`]) one-hot block — present flag, verb, object kind,
//! colour, and the PutNext second object — which the observation system
//! writes into every [`crate::batch::ObsBatch`] and the agents concatenate
//! onto the grid features, putting the goal on the policy's input the same
//! way NAVIX's JAX pipeline vmaps goal embeddings alongside observations.

use super::components::Color;
use super::entities::Tag;

/// Number of i32 features [`Mission::write_features`] writes:
/// 1 present flag + 3 verbs + 4 object kinds + 6 colours
/// + 4 second-object kinds + 6 second-object colours.
pub const MISSION_DIM: usize = 1 + 3 + 4 + 6 + 4 + 6;

/// Feature-block offsets (shared with the scan-path oracle in
/// [`crate::systems::observations::scan`]).
pub mod feat {
    /// `[PRESENT]` = 1 iff a mission is set.
    pub const PRESENT: usize = 0;
    /// One-hot verb block starts here (3 slots, [`super::MissionVerb`] order).
    pub const VERB: usize = 1;
    /// One-hot object-kind block (4 slots: door, key, ball, box).
    pub const KIND: usize = 4;
    /// One-hot colour block (6 slots, MiniGrid colour order).
    pub const COLOR: usize = 8;
    /// One-hot second-object kind block (PutNext target, 4 slots).
    pub const KIND2: usize = 14;
    /// One-hot second-object colour block (6 slots).
    pub const COLOR2: usize = 18;
}

/// The task verb of a mission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MissionVerb {
    /// Reach the target object and perform `done` facing it
    /// (GoToDoor, GoToObj).
    GoTo = 0,
    /// Pick the target object up (KeyCorridor, Fetch, UnlockPickup).
    PickUp = 1,
    /// Drop the target object on a cell 4-adjacent to the second object
    /// (PutNext).
    PutNext = 2,
}

/// Verb codes in bits 16..18. Code 0 is the *kind default* — the verb the
/// legacy `(tag << 8) | colour` encoding implied — so pre-existing mission
/// values decode unchanged.
const VERB_DEFAULT: i32 = 0;
const VERB_GOTO: i32 = 1;
const VERB_PUT_NEXT: i32 = 2;

/// One environment's mission, stored as the `i32` of
/// [`crate::core::state::BatchedState::mission`] (−1 = none).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mission(pub i32);

/// Dense slot of an object-kind tag inside the mission feature block.
#[inline]
fn kind_slot(tag: i32) -> usize {
    match tag {
        Tag::DOOR => 0,
        Tag::KEY => 1,
        Tag::BALL => 2,
        _ => {
            debug_assert_eq!(tag, Tag::BOX, "mission object kind must be door/key/ball/box");
            3
        }
    }
}

impl Mission {
    /// No mission set.
    pub const NONE: Mission = Mission(-1);

    /// Reinterpret a raw state value.
    #[inline]
    pub fn from_raw(raw: i32) -> Mission {
        Mission(raw)
    }

    /// The raw state value (what gets stored in `BatchedState::mission`).
    #[inline]
    pub fn raw(self) -> i32 {
        self.0
    }

    /// "Go to the `<colour>` `<kind>`": GoToDoor / GoToObj missions. A door
    /// target encodes with verb code 0, reproducing the legacy GoToDoor
    /// layout bit for bit.
    #[inline]
    pub fn go_to(kind_tag: i32, color: Color) -> Mission {
        let verb = if kind_tag == Tag::DOOR { VERB_DEFAULT } else { VERB_GOTO };
        Mission((verb << 16) | (kind_tag << 8) | color as i32)
    }

    /// "Pick up the `<colour>` `<kind>`": KeyCorridor / Fetch /
    /// UnlockPickup missions. Bit-identical to the legacy
    /// `(tag << 8) | colour` poke.
    #[inline]
    pub fn pick_up(kind_tag: i32, color: Color) -> Mission {
        debug_assert!(
            matches!(kind_tag, Tag::KEY | Tag::BALL | Tag::BOX),
            "only pickable kinds can be pick-up targets"
        );
        Mission((VERB_DEFAULT << 16) | (kind_tag << 8) | color as i32)
    }

    /// "Put the `<c1>` `<k1>` next to the `<c2>` `<k2>`" (PutNext).
    #[inline]
    pub fn put_next(kind_tag: i32, color: Color, near_tag: i32, near_color: Color) -> Mission {
        debug_assert!(
            matches!(kind_tag, Tag::KEY | Tag::BALL | Tag::BOX),
            "the moved object must be pickable"
        );
        Mission(
            ((near_color as i32) << 21)
                | (near_tag << 18)
                | (VERB_PUT_NEXT << 16)
                | (kind_tag << 8)
                | color as i32,
        )
    }

    #[inline]
    pub fn is_none(self) -> bool {
        self.0 < 0
    }

    /// The task verb (`None` when no mission is set).
    #[inline]
    pub fn verb(self) -> Option<MissionVerb> {
        if self.is_none() {
            return None;
        }
        Some(match (self.0 >> 16) & 0x3 {
            VERB_GOTO => MissionVerb::GoTo,
            VERB_PUT_NEXT => MissionVerb::PutNext,
            // Kind default: doors are go-to targets, pickables pick-up
            // targets — the verb the legacy encoding implied.
            _ => {
                if self.kind_tag() == Tag::DOOR {
                    MissionVerb::GoTo
                } else {
                    MissionVerb::PickUp
                }
            }
        })
    }

    /// Target object kind (a MiniGrid [`Tag`]; undefined when none).
    #[inline]
    pub fn kind_tag(self) -> i32 {
        (self.0 >> 8) & 0xFF
    }

    /// Target colour (undefined when none).
    #[inline]
    pub fn color(self) -> Color {
        Color::from_u8((self.0 & 0xFF) as u8)
    }

    /// Second object kind (PutNext target; undefined otherwise).
    #[inline]
    pub fn near_kind_tag(self) -> i32 {
        (self.0 >> 18) & 0x7
    }

    /// Second object colour (PutNext target; undefined otherwise).
    #[inline]
    pub fn near_color(self) -> Color {
        Color::from_u8(((self.0 >> 21) & 0x7) as u8)
    }

    /// Does `(tag, color)` match the mission's target object?
    #[inline]
    pub fn matches(self, tag: i32, color: Color) -> bool {
        !self.is_none() && self.kind_tag() == tag && self.color() == color
    }

    /// Is this a go-to mission targeting exactly `(tag, color)`?
    #[inline]
    pub fn is_go_to(self, tag: i32, color: Color) -> bool {
        self.verb() == Some(MissionVerb::GoTo) && self.matches(tag, color)
    }

    /// Is this a pick-up mission targeting exactly `(tag, color)`?
    #[inline]
    pub fn is_pick_up(self, tag: i32, color: Color) -> bool {
        self.verb() == Some(MissionVerb::PickUp) && self.matches(tag, color)
    }

    /// Human-readable mission string (the BabyAI-style instruction).
    pub fn describe(self) -> String {
        let kind = |t: i32| match t {
            Tag::DOOR => "door",
            Tag::KEY => "key",
            Tag::BALL => "ball",
            _ => "box",
        };
        match self.verb() {
            None => "none".to_string(),
            Some(MissionVerb::GoTo) => {
                format!("go to the {} {}", self.color().name(), kind(self.kind_tag()))
            }
            Some(MissionVerb::PickUp) => {
                format!("pick up the {} {}", self.color().name(), kind(self.kind_tag()))
            }
            Some(MissionVerb::PutNext) => format!(
                "put the {} {} next to the {} {}",
                self.color().name(),
                kind(self.kind_tag()),
                self.near_color().name(),
                kind(self.near_kind_tag()),
            ),
        }
    }

    /// Render the mission as the fixed-width one-hot feature block every
    /// observation batch carries (`out.len() == MISSION_DIM`). All-zero when
    /// no mission is set, so mission-free families are unaffected by the
    /// concatenation.
    pub fn write_features(self, out: &mut [i32]) {
        debug_assert_eq!(out.len(), MISSION_DIM);
        out.fill(0);
        let Some(verb) = self.verb() else { return };
        out[feat::PRESENT] = 1;
        out[feat::VERB + verb as usize] = 1;
        out[feat::KIND + kind_slot(self.kind_tag())] = 1;
        out[feat::COLOR + self.color() as usize] = 1;
        if verb == MissionVerb::PutNext {
            out[feat::KIND2 + kind_slot(self.near_kind_tag())] = 1;
            out[feat::COLOR2 + self.near_color() as usize] = 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_layout_is_preserved() {
        // The invariance every pre-existing shard/parity pin depends on:
        // typed constructors reproduce the ad-hoc pokes bit for bit.
        assert_eq!(
            Mission::go_to(Tag::DOOR, Color::Yellow).raw(),
            (Tag::DOOR << 8) | Color::Yellow as i32
        );
        for tag in [Tag::KEY, Tag::BALL, Tag::BOX] {
            for color in Color::ALL {
                assert_eq!(Mission::pick_up(tag, color).raw(), (tag << 8) | color as i32);
            }
        }
        assert_eq!(Mission::NONE.raw(), -1);
    }

    #[test]
    fn verbs_round_trip() {
        let m = Mission::go_to(Tag::DOOR, Color::Red);
        assert_eq!(m.verb(), Some(MissionVerb::GoTo));
        assert_eq!((m.kind_tag(), m.color()), (Tag::DOOR, Color::Red));

        let m = Mission::go_to(Tag::BALL, Color::Blue);
        assert_eq!(m.verb(), Some(MissionVerb::GoTo));
        assert_eq!((m.kind_tag(), m.color()), (Tag::BALL, Color::Blue));
        assert!(m.is_go_to(Tag::BALL, Color::Blue));
        assert!(!m.is_pick_up(Tag::BALL, Color::Blue), "GoTo(ball) is not a pickup mission");

        let m = Mission::pick_up(Tag::KEY, Color::Grey);
        assert_eq!(m.verb(), Some(MissionVerb::PickUp));
        assert!(m.is_pick_up(Tag::KEY, Color::Grey));
        assert!(!m.is_go_to(Tag::KEY, Color::Grey));

        let m = Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green);
        assert_eq!(m.verb(), Some(MissionVerb::PutNext));
        assert_eq!((m.kind_tag(), m.color()), (Tag::BALL, Color::Purple));
        assert_eq!((m.near_kind_tag(), m.near_color()), (Tag::BOX, Color::Green));

        assert_eq!(Mission::NONE.verb(), None);
        assert!(!Mission::NONE.matches(Tag::KEY, Color::Red));
    }

    #[test]
    fn features_are_one_hot_blocks() {
        let mut f = [0i32; MISSION_DIM];
        Mission::NONE.write_features(&mut f);
        assert!(f.iter().all(|&x| x == 0), "no mission → all-zero features");

        Mission::go_to(Tag::DOOR, Color::Yellow).write_features(&mut f);
        assert_eq!(f[feat::PRESENT], 1);
        assert_eq!(f[feat::VERB + MissionVerb::GoTo as usize], 1);
        assert_eq!(f[feat::KIND], 1, "door slot");
        assert_eq!(f[feat::COLOR + Color::Yellow as usize], 1);
        assert_eq!(f.iter().sum::<i32>(), 4, "exactly one bit per block");

        Mission::put_next(Tag::KEY, Color::Red, Tag::BALL, Color::Grey).write_features(&mut f);
        assert_eq!(f[feat::PRESENT], 1);
        assert_eq!(f[feat::VERB + MissionVerb::PutNext as usize], 1);
        assert_eq!(f[feat::KIND + 1], 1, "key slot");
        assert_eq!(f[feat::COLOR + Color::Red as usize], 1);
        assert_eq!(f[feat::KIND2 + 2], 1, "ball slot");
        assert_eq!(f[feat::COLOR2 + Color::Grey as usize], 1);
        assert_eq!(f.iter().sum::<i32>(), 6);

        // every feature is 0/1 (the conformance sweep pins this per env)
        for m in [
            Mission::pick_up(Tag::BOX, Color::Green),
            Mission::go_to(Tag::KEY, Color::Blue),
            Mission::put_next(Tag::BALL, Color::Red, Tag::BOX, Color::Purple),
        ] {
            m.write_features(&mut f);
            assert!(f.iter().all(|&x| x == 0 || x == 1));
        }
    }

    #[test]
    fn describe_reads_like_babyai() {
        assert_eq!(Mission::go_to(Tag::DOOR, Color::Red).describe(), "go to the red door");
        assert_eq!(Mission::pick_up(Tag::KEY, Color::Blue).describe(), "pick up the blue key");
        assert_eq!(
            Mission::put_next(Tag::BALL, Color::Green, Tag::BOX, Color::Yellow).describe(),
            "put the green ball next to the yellow box"
        );
        assert_eq!(Mission::NONE.describe(), "none");
    }
}
