//! The compositional mission grammar — goal conditioning as first-class state.
//!
//! NAVIX positions MiniGrid as a substrate for *language-conditioned* RL:
//! several families (GoToDoor, KeyCorridor, Fetch, Unlock/UnlockPickup, and
//! the BabyAI-style GoToObj/PutNext families) parameterise each episode with
//! a goal — "go to the red door", "pick up the blue key", "put the ball next
//! to the box". PR 5 promoted that goal from an ad-hoc `i32` poke to the
//! typed [`Mission`] component; this module grows it into a *grammar*:
//!
//! * **[`MissionClause`]** — one atomic instruction: a verb
//!   ([`MissionVerb`]: go to / pick up / open / put next to) applied to an
//!   object kind × colour (plus a second object for `PutNext`);
//! * **[`MissionSpec`]** — a small AST over clauses: a single clause, or a
//!   2-step `then` sequence ("open the red door, then pick up the box")
//!   with per-clause completion latches and an active-clause cursor;
//! * **the token slab** — every spec serialises losslessly into a
//!   fixed-capacity `[i32; MAX_MISSION_TOKENS]` buffer
//!   ([`MissionSpec::write_tokens`] / [`MissionSpec::from_tokens`]) which is
//!   what [`crate::core::state::BatchedState`] stores per agent-row and what
//!   the observation system streams to the policy (replacing the PR 5
//!   one-hot block).
//!
//! ## Packed clause layout (preserved from the legacy `(tag << 8) | colour`)
//!
//! Each clause still round-trips through the PR 5 packed `i32` — the state's
//! `mission` column always holds the *active* clause in this layout, so the
//! intervention system, the shard-invariance pins, and every pre-grammar
//! mission value stay bit-identical:
//!
//! ```text
//! bit 0..8    target colour                 (Color as u8)
//! bit 8..16   target object kind            (MiniGrid Tag)
//! bit 16..18  verb code: 0 = kind default   (GoTo for Door, PickUp for
//!             pickables — the legacy implicit verb), 1 = explicit GoTo,
//!             2 = PutNext, 3 = Open
//! bit 18..21  second object kind            (PutNext only; Tag fits 3 bits)
//! bit 21..24  second object colour          (PutNext only)
//! ```
//!
//! `-1` (all bits set, sign negative) means "no mission".
//!
//! ## Token layout
//!
//! [`MISSION_TOKENS`] = 16 small non-negative integers; 0 is always "absent"
//! so mission-free families keep an all-zero block:
//!
//! ```text
//! tok[0]          clause count (0, 1 or 2; 0 = no mission)
//! tok[1]          active clause index (0-based)
//! tok[2 + 7c + 0] clause c verb   = MissionVerb as i32 + 1
//! tok[2 + 7c + 1] clause c kind   = kind slot (door/key/ball/box) + 1
//! tok[2 + 7c + 2] clause c colour = Color as i32 + 1
//! tok[2 + 7c + 3] clause c second-object kind slot + 1 (PutNext; else 0)
//! tok[2 + 7c + 4] clause c second-object colour + 1    (PutNext; else 0)
//! tok[2 + 7c + 5] clause c completion latch (0/1)
//! tok[2 + 7c + 6] reserved (0)
//! ```
//!
//! A 1-clause spec is the **lossless embedding** of a legacy [`Mission`]:
//! [`MissionSpec::from_mission`] followed by [`MissionSpec::active_mission`]
//! reproduces the packed `i32` bit for bit, which is what keeps every
//! pre-grammar parity pin alive.

use super::components::Color;
use super::entities::Tag;

/// Width of the tokenised mission block every observation carries:
/// 2 header tokens + [`MAX_CLAUSES`] × 7 clause tokens.
pub const MISSION_TOKENS: usize = 2 + MAX_CLAUSES * CLAUSE_STRIDE;

/// Capacity of the per-agent-row token slab in
/// [`crate::core::state::BatchedState`] (same as [`MISSION_TOKENS`]: the
/// slab is streamed verbatim into the feature block).
pub const MAX_MISSION_TOKENS: usize = MISSION_TOKENS;

/// Maximum clauses a [`MissionSpec`] holds (the `then` sequencer is 2-step).
pub const MAX_CLAUSES: usize = 2;

/// Tokens per clause in the slab (verb, kind, colour, near-kind,
/// near-colour, done latch, reserved).
pub const CLAUSE_STRIDE: usize = 7;

/// First clause token (after the count/active header).
pub const CLAUSE_BASE: usize = 2;

/// The task verb of a mission clause.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MissionVerb {
    /// Reach the target object and perform `done` facing it
    /// (GoToDoor, GoToObj).
    GoTo = 0,
    /// Pick the target object up (KeyCorridor, Fetch, UnlockPickup).
    PickUp = 1,
    /// Drop the target object on a cell 4-adjacent to the second object
    /// (PutNext).
    PutNext = 2,
    /// Toggle the target door open (SeqUnlockPickup, OpenDoorsOrder).
    Open = 3,
}

impl MissionVerb {
    /// All verbs, discriminant order (token code = index + 1).
    pub const ALL: [MissionVerb; 4] =
        [MissionVerb::GoTo, MissionVerb::PickUp, MissionVerb::PutNext, MissionVerb::Open];
}

/// Verb codes in bits 16..18. Code 0 is the *kind default* — the verb the
/// legacy `(tag << 8) | colour` encoding implied — so pre-existing mission
/// values decode unchanged.
const VERB_DEFAULT: i32 = 0;
const VERB_GOTO: i32 = 1;
const VERB_PUT_NEXT: i32 = 2;
const VERB_OPEN: i32 = 3;

/// One clause's packed `i32` — what
/// [`crate::core::state::BatchedState::mission`] holds for the *active*
/// clause (−1 = none).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mission(pub i32);

/// Dense slot of an object-kind tag inside the mission token block.
#[inline]
fn kind_slot(tag: i32) -> usize {
    match tag {
        Tag::DOOR => 0,
        Tag::KEY => 1,
        Tag::BALL => 2,
        _ => {
            debug_assert_eq!(tag, Tag::BOX, "mission object kind must be door/key/ball/box");
            3
        }
    }
}

/// Inverse of [`kind_slot`].
#[inline]
fn slot_kind(slot: i32) -> i32 {
    match slot {
        0 => Tag::DOOR,
        1 => Tag::KEY,
        2 => Tag::BALL,
        _ => Tag::BOX,
    }
}

impl Mission {
    /// No mission set.
    pub const NONE: Mission = Mission(-1);

    /// Reinterpret a raw state value.
    #[inline]
    pub fn from_raw(raw: i32) -> Mission {
        Mission(raw)
    }

    /// The raw state value (what gets stored in `BatchedState::mission`).
    #[inline]
    pub fn raw(self) -> i32 {
        self.0
    }

    /// "Go to the `<colour>` `<kind>`": GoToDoor / GoToObj missions. A door
    /// target encodes with verb code 0, reproducing the legacy GoToDoor
    /// layout bit for bit.
    #[inline]
    pub fn go_to(kind_tag: i32, color: Color) -> Mission {
        let verb = if kind_tag == Tag::DOOR { VERB_DEFAULT } else { VERB_GOTO };
        Mission((verb << 16) | (kind_tag << 8) | color as i32)
    }

    /// "Pick up the `<colour>` `<kind>`": KeyCorridor / Fetch /
    /// UnlockPickup missions. Bit-identical to the legacy
    /// `(tag << 8) | colour` poke.
    #[inline]
    pub fn pick_up(kind_tag: i32, color: Color) -> Mission {
        debug_assert!(
            matches!(kind_tag, Tag::KEY | Tag::BALL | Tag::BOX),
            "only pickable kinds can be pick-up targets"
        );
        Mission((VERB_DEFAULT << 16) | (kind_tag << 8) | color as i32)
    }

    /// "Open the `<colour>` door" (SeqUnlockPickup, OpenDoorsOrder). An
    /// explicit verb code distinguishes it from GoToDoor's kind-default.
    #[inline]
    pub fn open(color: Color) -> Mission {
        Mission((VERB_OPEN << 16) | (Tag::DOOR << 8) | color as i32)
    }

    /// "Put the `<c1>` `<k1>` next to the `<c2>` `<k2>`" (PutNext).
    #[inline]
    pub fn put_next(kind_tag: i32, color: Color, near_tag: i32, near_color: Color) -> Mission {
        debug_assert!(
            matches!(kind_tag, Tag::KEY | Tag::BALL | Tag::BOX),
            "the moved object must be pickable"
        );
        Mission(
            ((near_color as i32) << 21)
                | (near_tag << 18)
                | (VERB_PUT_NEXT << 16)
                | (kind_tag << 8)
                | color as i32,
        )
    }

    #[inline]
    pub fn is_none(self) -> bool {
        self.0 < 0
    }

    /// The task verb (`None` when no mission is set).
    #[inline]
    pub fn verb(self) -> Option<MissionVerb> {
        if self.is_none() {
            return None;
        }
        Some(match (self.0 >> 16) & 0x3 {
            VERB_GOTO => MissionVerb::GoTo,
            VERB_PUT_NEXT => MissionVerb::PutNext,
            VERB_OPEN => MissionVerb::Open,
            // Kind default: doors are go-to targets, pickables pick-up
            // targets — the verb the legacy encoding implied.
            _ => {
                if self.kind_tag() == Tag::DOOR {
                    MissionVerb::GoTo
                } else {
                    MissionVerb::PickUp
                }
            }
        })
    }

    /// Target object kind (a MiniGrid [`Tag`]; undefined when none).
    #[inline]
    pub fn kind_tag(self) -> i32 {
        (self.0 >> 8) & 0xFF
    }

    /// Target colour (undefined when none).
    #[inline]
    pub fn color(self) -> Color {
        Color::from_u8((self.0 & 0xFF) as u8)
    }

    /// Second object kind (PutNext target; undefined otherwise).
    #[inline]
    pub fn near_kind_tag(self) -> i32 {
        (self.0 >> 18) & 0x7
    }

    /// Second object colour (PutNext target; undefined otherwise).
    #[inline]
    pub fn near_color(self) -> Color {
        Color::from_u8(((self.0 >> 21) & 0x7) as u8)
    }

    /// Does `(tag, color)` match the mission's target object?
    #[inline]
    pub fn matches(self, tag: i32, color: Color) -> bool {
        !self.is_none() && self.kind_tag() == tag && self.color() == color
    }

    /// Is this a go-to mission targeting exactly `(tag, color)`?
    #[inline]
    pub fn is_go_to(self, tag: i32, color: Color) -> bool {
        self.verb() == Some(MissionVerb::GoTo) && self.matches(tag, color)
    }

    /// Is this a pick-up mission targeting exactly `(tag, color)`?
    #[inline]
    pub fn is_pick_up(self, tag: i32, color: Color) -> bool {
        self.verb() == Some(MissionVerb::PickUp) && self.matches(tag, color)
    }

    /// Is this an open mission targeting the `(color)` door?
    #[inline]
    pub fn is_open(self, color: Color) -> bool {
        self.verb() == Some(MissionVerb::Open) && self.matches(Tag::DOOR, color)
    }

    /// Human-readable mission string (the BabyAI-style instruction).
    pub fn describe(self) -> String {
        match MissionClause::from_mission(self) {
            None => "none".to_string(),
            Some(c) => c.describe(),
        }
    }

    /// Render this (single-clause) mission as the fixed-width token block
    /// (`out.len() == MISSION_TOKENS`) via the lossless 1-clause embedding.
    /// All-zero when no mission is set, so mission-free families are
    /// unaffected by the concatenation.
    pub fn write_features(self, out: &mut [i32]) {
        MissionSpec::from_mission(self).write_tokens(out);
    }
}

/// One atomic instruction of the mission grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MissionClause {
    /// Reach the `(kind, color)` object and perform `done` facing it.
    GoTo { kind: i32, color: Color },
    /// Pick the `(kind, color)` object up.
    PickUp { kind: i32, color: Color },
    /// Toggle the `color` door open.
    Open { color: Color },
    /// Drop the `(kind, color)` object 4-adjacent to `(near_kind,
    /// near_color)`.
    PutNext { kind: i32, color: Color, near_kind: i32, near_color: Color },
}

impl MissionClause {
    /// The clause's verb.
    #[inline]
    pub fn verb(self) -> MissionVerb {
        match self {
            MissionClause::GoTo { .. } => MissionVerb::GoTo,
            MissionClause::PickUp { .. } => MissionVerb::PickUp,
            MissionClause::Open { .. } => MissionVerb::Open,
            MissionClause::PutNext { .. } => MissionVerb::PutNext,
        }
    }

    /// Pack into the legacy clause `i32` — **lossless**: 1-clause specs
    /// round-trip bit-for-bit through this, which is what every pre-grammar
    /// parity pin rides on.
    pub fn to_mission(self) -> Mission {
        match self {
            MissionClause::GoTo { kind, color } => Mission::go_to(kind, color),
            MissionClause::PickUp { kind, color } => Mission::pick_up(kind, color),
            MissionClause::Open { color } => Mission::open(color),
            MissionClause::PutNext { kind, color, near_kind, near_color } => {
                Mission::put_next(kind, color, near_kind, near_color)
            }
        }
    }

    /// Decode a packed clause `i32` (`None` when no mission is set).
    pub fn from_mission(m: Mission) -> Option<MissionClause> {
        let verb = m.verb()?;
        Some(match verb {
            MissionVerb::GoTo => MissionClause::GoTo { kind: m.kind_tag(), color: m.color() },
            MissionVerb::PickUp => MissionClause::PickUp { kind: m.kind_tag(), color: m.color() },
            MissionVerb::Open => MissionClause::Open { color: m.color() },
            MissionVerb::PutNext => MissionClause::PutNext {
                kind: m.kind_tag(),
                color: m.color(),
                near_kind: m.near_kind_tag(),
                near_color: m.near_color(),
            },
        })
    }

    /// Human-readable clause string (the BabyAI-style instruction).
    pub fn describe(self) -> String {
        let kind_name = |t: i32| match t {
            Tag::DOOR => "door",
            Tag::KEY => "key",
            Tag::BALL => "ball",
            _ => "box",
        };
        match self {
            MissionClause::GoTo { kind, color } => {
                format!("go to the {} {}", color.name(), kind_name(kind))
            }
            MissionClause::PickUp { kind, color } => {
                format!("pick up the {} {}", color.name(), kind_name(kind))
            }
            MissionClause::Open { color } => format!("open the {} door", color.name()),
            MissionClause::PutNext { kind, color, near_kind, near_color } => format!(
                "put the {} {} next to the {} {}",
                color.name(),
                kind_name(kind),
                near_color.name(),
                kind_name(near_kind),
            ),
        }
    }
}

/// A compositional mission: up to [`MAX_CLAUSES`] clauses executed in
/// sequence, with per-clause completion latches and an active-clause cursor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MissionSpec {
    clauses: [Option<MissionClause>; MAX_CLAUSES],
    len: usize,
    active: usize,
    done: [bool; MAX_CLAUSES],
}

impl MissionSpec {
    /// No mission.
    pub const EMPTY: MissionSpec =
        MissionSpec { clauses: [None; MAX_CLAUSES], len: 0, active: 0, done: [false; MAX_CLAUSES] };

    /// A single-clause mission.
    pub fn single(clause: MissionClause) -> MissionSpec {
        let mut s = MissionSpec::EMPTY;
        s.clauses[0] = Some(clause);
        s.len = 1;
        s
    }

    /// "`first`, then `second`" — the 2-step sequencer.
    pub fn then(first: MissionClause, second: MissionClause) -> MissionSpec {
        let mut s = MissionSpec::single(first);
        s.clauses[1] = Some(second);
        s.len = 2;
        s
    }

    /// The lossless 1-clause embedding of a legacy packed mission
    /// ([`Mission::NONE`] → [`MissionSpec::EMPTY`]).
    pub fn from_mission(m: Mission) -> MissionSpec {
        match MissionClause::from_mission(m) {
            None => MissionSpec::EMPTY,
            Some(c) => MissionSpec::single(c),
        }
    }

    /// Number of clauses (0 = no mission).
    #[inline]
    pub fn len(self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.len == 0
    }

    /// Index of the clause currently being pursued.
    #[inline]
    pub fn active_index(self) -> usize {
        self.active
    }

    /// Clause `i` (`None` past the end).
    #[inline]
    pub fn clause(self, i: usize) -> Option<MissionClause> {
        if i < self.len {
            self.clauses[i]
        } else {
            None
        }
    }

    /// Has clause `i` completed?
    #[inline]
    pub fn is_done(self, i: usize) -> bool {
        i < self.len && self.done[i]
    }

    /// Have all clauses completed?
    #[inline]
    pub fn is_complete(self) -> bool {
        self.len > 0 && (0..self.len).all(|i| self.done[i])
    }

    /// The clause currently being pursued (`None` when empty or complete).
    #[inline]
    pub fn active_clause(self) -> Option<MissionClause> {
        if self.is_complete() {
            return None;
        }
        self.clause(self.active)
    }

    /// The active clause as a packed legacy mission — what the state's
    /// `mission` column holds. For 1-clause specs this is the original
    /// mission value bit-for-bit.
    #[inline]
    pub fn active_mission(self) -> Mission {
        match self.active_clause() {
            None => Mission::NONE,
            Some(c) => c.to_mission(),
        }
    }

    /// Latch the active clause complete and advance the cursor. Returns
    /// `true` when this completed the *whole* mission (the last clause).
    pub fn mark_active_done(&mut self) -> bool {
        if self.len == 0 || self.done[self.active] {
            return false;
        }
        self.done[self.active] = true;
        if self.active + 1 < self.len {
            self.active += 1;
            false
        } else {
            true
        }
    }

    /// Human-readable mission string ("open the red door, then pick up the
    /// green box").
    pub fn describe(self) -> String {
        if self.len == 0 {
            return "none".to_string();
        }
        let mut s = self.clauses[0].expect("clause 0 present").describe();
        for i in 1..self.len {
            s.push_str(", then ");
            s.push_str(&self.clauses[i].expect("clause present").describe());
        }
        s
    }

    /// Serialise into the fixed-width token slab
    /// (`out.len() == MISSION_TOKENS`; all-zero when empty).
    pub fn write_tokens(self, out: &mut [i32]) {
        debug_assert_eq!(out.len(), MISSION_TOKENS);
        out.fill(0);
        if self.len == 0 {
            return;
        }
        out[0] = self.len as i32;
        out[1] = self.active as i32;
        for c in 0..self.len {
            let base = CLAUSE_BASE + c * CLAUSE_STRIDE;
            let clause = self.clauses[c].expect("clause within len is present");
            out[base] = clause.verb() as i32 + 1;
            let (kind, color, near) = match clause {
                MissionClause::GoTo { kind, color } | MissionClause::PickUp { kind, color } => {
                    (kind, color, None)
                }
                MissionClause::Open { color } => (Tag::DOOR, color, None),
                MissionClause::PutNext { kind, color, near_kind, near_color } => {
                    (kind, color, Some((near_kind, near_color)))
                }
            };
            out[base + 1] = kind_slot(kind) as i32 + 1;
            out[base + 2] = color as i32 + 1;
            if let Some((nk, nc)) = near {
                out[base + 3] = kind_slot(nk) as i32 + 1;
                out[base + 4] = nc as i32 + 1;
            }
            out[base + 5] = self.done[c] as i32;
        }
    }

    /// Deserialise a token slab written by [`MissionSpec::write_tokens`].
    /// Malformed slabs decode defensively (clamped counts, absent clauses
    /// skipped) rather than panicking — the slab crosses the snapshot codec.
    pub fn from_tokens(toks: &[i32]) -> MissionSpec {
        debug_assert_eq!(toks.len(), MISSION_TOKENS);
        let mut s = MissionSpec::EMPTY;
        let n = toks[0].clamp(0, MAX_CLAUSES as i32) as usize;
        if n == 0 {
            return s;
        }
        for c in 0..n {
            let base = CLAUSE_BASE + c * CLAUSE_STRIDE;
            let verb_tok = toks[base];
            if verb_tok <= 0 {
                break;
            }
            let kind = slot_kind(toks[base + 1] - 1);
            let color = Color::from_u8((toks[base + 2] - 1).max(0) as u8);
            let clause = match verb_tok - 1 {
                x if x == MissionVerb::GoTo as i32 => MissionClause::GoTo { kind, color },
                x if x == MissionVerb::PickUp as i32 => MissionClause::PickUp { kind, color },
                x if x == MissionVerb::Open as i32 => MissionClause::Open { color },
                _ => MissionClause::PutNext {
                    kind,
                    color,
                    near_kind: slot_kind(toks[base + 3] - 1),
                    near_color: Color::from_u8((toks[base + 4] - 1).max(0) as u8),
                },
            };
            s.clauses[s.len] = Some(clause);
            s.done[s.len] = toks[base + 5] != 0;
            s.len += 1;
        }
        if s.len == 0 {
            return MissionSpec::EMPTY;
        }
        s.active = (toks[1].clamp(0, s.len as i32 - 1)) as usize;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_layout_is_preserved() {
        // The invariance every pre-existing shard/parity pin depends on:
        // typed constructors reproduce the ad-hoc pokes bit for bit.
        assert_eq!(
            Mission::go_to(Tag::DOOR, Color::Yellow).raw(),
            (Tag::DOOR << 8) | Color::Yellow as i32
        );
        for tag in [Tag::KEY, Tag::BALL, Tag::BOX] {
            for color in Color::ALL {
                assert_eq!(Mission::pick_up(tag, color).raw(), (tag << 8) | color as i32);
            }
        }
        assert_eq!(Mission::NONE.raw(), -1);
    }

    #[test]
    fn verbs_round_trip() {
        let m = Mission::go_to(Tag::DOOR, Color::Red);
        assert_eq!(m.verb(), Some(MissionVerb::GoTo));
        assert_eq!((m.kind_tag(), m.color()), (Tag::DOOR, Color::Red));

        let m = Mission::go_to(Tag::BALL, Color::Blue);
        assert_eq!(m.verb(), Some(MissionVerb::GoTo));
        assert_eq!((m.kind_tag(), m.color()), (Tag::BALL, Color::Blue));
        assert!(m.is_go_to(Tag::BALL, Color::Blue));
        assert!(!m.is_pick_up(Tag::BALL, Color::Blue), "GoTo(ball) is not a pickup mission");

        let m = Mission::pick_up(Tag::KEY, Color::Grey);
        assert_eq!(m.verb(), Some(MissionVerb::PickUp));
        assert!(m.is_pick_up(Tag::KEY, Color::Grey));
        assert!(!m.is_go_to(Tag::KEY, Color::Grey));

        let m = Mission::open(Color::Yellow);
        assert_eq!(m.verb(), Some(MissionVerb::Open));
        assert!(m.is_open(Color::Yellow));
        assert!(!m.is_go_to(Tag::DOOR, Color::Yellow), "Open(door) is not a go-to mission");
        assert_ne!(
            m.raw(),
            Mission::go_to(Tag::DOOR, Color::Yellow).raw(),
            "the explicit Open verb code distinguishes it from GoToDoor"
        );

        let m = Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green);
        assert_eq!(m.verb(), Some(MissionVerb::PutNext));
        assert_eq!((m.kind_tag(), m.color()), (Tag::BALL, Color::Purple));
        assert_eq!((m.near_kind_tag(), m.near_color()), (Tag::BOX, Color::Green));

        assert_eq!(Mission::NONE.verb(), None);
        assert!(!Mission::NONE.matches(Tag::KEY, Color::Red));
    }

    #[test]
    fn token_block_layout() {
        let mut f = [0i32; MISSION_TOKENS];
        Mission::NONE.write_features(&mut f);
        assert!(f.iter().all(|&x| x == 0), "no mission → all-zero tokens");

        Mission::go_to(Tag::DOOR, Color::Yellow).write_features(&mut f);
        assert_eq!(f[0], 1, "one clause");
        assert_eq!(f[1], 0, "clause 0 active");
        assert_eq!(f[CLAUSE_BASE], MissionVerb::GoTo as i32 + 1);
        assert_eq!(f[CLAUSE_BASE + 1], 1, "door slot + 1");
        assert_eq!(f[CLAUSE_BASE + 2], Color::Yellow as i32 + 1);
        assert_eq!(&f[CLAUSE_BASE + 3..], &[0; MISSION_TOKENS - CLAUSE_BASE - 3]);

        Mission::put_next(Tag::KEY, Color::Red, Tag::BALL, Color::Grey).write_features(&mut f);
        assert_eq!(f[CLAUSE_BASE], MissionVerb::PutNext as i32 + 1);
        assert_eq!(f[CLAUSE_BASE + 1], 2, "key slot + 1");
        assert_eq!(f[CLAUSE_BASE + 2], Color::Red as i32 + 1);
        assert_eq!(f[CLAUSE_BASE + 3], 3, "ball slot + 1");
        assert_eq!(f[CLAUSE_BASE + 4], Color::Grey as i32 + 1);

        // every token is a small non-negative integer (the conformance
        // sweep pins this per env)
        for m in [
            Mission::pick_up(Tag::BOX, Color::Green),
            Mission::go_to(Tag::KEY, Color::Blue),
            Mission::open(Color::Red),
            Mission::put_next(Tag::BALL, Color::Red, Tag::BOX, Color::Purple),
        ] {
            m.write_features(&mut f);
            assert!(f.iter().all(|&x| (0..=7).contains(&x)));
        }
    }

    #[test]
    fn spec_tokens_round_trip() {
        // AST → tokens → AST round-trip pin, across clause shapes and
        // progress states.
        let clauses = [
            MissionClause::GoTo { kind: Tag::DOOR, color: Color::Red },
            MissionClause::PickUp { kind: Tag::BOX, color: Color::Green },
            MissionClause::Open { color: Color::Blue },
            MissionClause::PutNext {
                kind: Tag::BALL,
                color: Color::Purple,
                near_kind: Tag::BOX,
                near_color: Color::Yellow,
            },
        ];
        let mut buf = [0i32; MISSION_TOKENS];
        for &a in &clauses {
            let s = MissionSpec::single(a);
            s.write_tokens(&mut buf);
            assert_eq!(MissionSpec::from_tokens(&buf), s, "{:?}", a);
            for &b in &clauses {
                let mut s = MissionSpec::then(a, b);
                s.write_tokens(&mut buf);
                assert_eq!(MissionSpec::from_tokens(&buf), s);
                // advance mid-sequence and re-pin
                assert!(!s.mark_active_done(), "first clause is not the last");
                assert_eq!(s.active_index(), 1);
                s.write_tokens(&mut buf);
                assert_eq!(MissionSpec::from_tokens(&buf), s);
                assert!(s.mark_active_done(), "second clause completes the mission");
                assert!(s.is_complete());
                s.write_tokens(&mut buf);
                assert_eq!(MissionSpec::from_tokens(&buf), s);
            }
        }
        MissionSpec::EMPTY.write_tokens(&mut buf);
        assert_eq!(buf, [0; MISSION_TOKENS]);
        assert_eq!(MissionSpec::from_tokens(&buf), MissionSpec::EMPTY);
    }

    #[test]
    fn legacy_embedding_is_lossless() {
        // 1-clause specs embed legacy missions bit-for-bit: packed →
        // spec → packed is the identity, including verb-code subtleties
        // (kind-default vs explicit GoTo).
        let missions = [
            Mission::go_to(Tag::DOOR, Color::Yellow),
            Mission::go_to(Tag::BALL, Color::Blue),
            Mission::pick_up(Tag::KEY, Color::Grey),
            Mission::pick_up(Tag::BOX, Color::Red),
            Mission::open(Color::Green),
            Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green),
        ];
        for m in missions {
            let spec = MissionSpec::from_mission(m);
            assert_eq!(spec.len(), 1);
            assert_eq!(spec.active_mission().raw(), m.raw(), "{}", m.describe());
            // and through the token slab too
            let mut buf = [0i32; MISSION_TOKENS];
            spec.write_tokens(&mut buf);
            assert_eq!(MissionSpec::from_tokens(&buf).active_mission().raw(), m.raw());
        }
        assert_eq!(MissionSpec::from_mission(Mission::NONE), MissionSpec::EMPTY);
        assert_eq!(MissionSpec::EMPTY.active_mission().raw(), -1);
    }

    #[test]
    fn clause_advance_latches() {
        let mut s = MissionSpec::then(
            MissionClause::Open { color: Color::Red },
            MissionClause::PickUp { kind: Tag::BOX, color: Color::Green },
        );
        assert_eq!(s.active_index(), 0);
        assert_eq!(s.active_mission().raw(), Mission::open(Color::Red).raw());
        assert!(!s.is_complete());

        assert!(!s.mark_active_done(), "clause 1/2 done must not complete the mission");
        assert!(s.is_done(0));
        assert!(!s.is_done(1));
        assert_eq!(s.active_index(), 1);
        assert_eq!(s.active_mission().raw(), Mission::pick_up(Tag::BOX, Color::Green).raw());

        assert!(s.mark_active_done(), "clause 2/2 done completes the mission");
        assert!(s.is_complete());
        assert_eq!(s.active_mission().raw(), -1, "complete mission has no active clause");
        assert!(!s.mark_active_done(), "idempotent once complete");
    }

    #[test]
    fn describe_reads_like_babyai() {
        assert_eq!(Mission::go_to(Tag::DOOR, Color::Red).describe(), "go to the red door");
        assert_eq!(Mission::pick_up(Tag::KEY, Color::Blue).describe(), "pick up the blue key");
        assert_eq!(Mission::open(Color::Grey).describe(), "open the grey door");
        assert_eq!(
            Mission::put_next(Tag::BALL, Color::Green, Tag::BOX, Color::Yellow).describe(),
            "put the green ball next to the yellow box"
        );
        assert_eq!(Mission::NONE.describe(), "none");
        assert_eq!(
            MissionSpec::then(
                MissionClause::Open { color: Color::Red },
                MissionClause::PickUp { kind: Tag::BOX, color: Color::Green },
            )
            .describe(),
            "open the red door, then pick up the green box"
        );
    }
}
