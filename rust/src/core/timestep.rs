//! The paper's stateful environment interface (§3.2.2): the `Timestep`.
//!
//! A timestep is the tuple `(t, o_t, a_t, r_{t+1}, γ_{t+1}, s_t, i_{t+1})`.
//! Both `reset` and `step` return this same schema, which lets environments
//! autoreset and keeps agent code branch-free — the property that makes the
//! whole interaction loop jittable in the original and allocation-free here.
//!
//! In the batched engine the "state" member lives inside
//! [`crate::batch::BatchedEnv`]'s [`crate::core::state::BatchedState`];
//! this module defines the per-env scalar metadata and the batched
//! observation/reward/discount buffers.

/// Where a timestep sits within an episode (dm_env-style).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum StepType {
    /// First timestep after a reset (no preceding action/reward).
    First = 0,
    /// Ordinary transition.
    Mid = 1,
    /// Episode ended by a terminal event (γ_{t+1} = 0).
    Terminated = 2,
    /// Episode ended by timeout (truncation: γ_{t+1} stays γ).
    Truncated = 3,
}

impl StepType {
    #[inline]
    pub fn is_last(self) -> bool {
        matches!(self, StepType::Terminated | StepType::Truncated)
    }
}

/// Scalar (single-env) timestep, used by the baseline engine, agents and the
/// scalar convenience API. Observations are passed separately (the batched
/// engine writes them into reusable buffers).
#[derive(Clone, Debug)]
pub struct Timestep {
    /// Steps elapsed since the last reset.
    pub t: u32,
    /// The action that produced this timestep (−1 on reset, per the paper's
    /// padding convention).
    pub action: i32,
    /// Reward r_{t+1} (0.0 on reset).
    pub reward: f32,
    /// Discount γ_{t+1}: 0 on termination, γ otherwise.
    pub discount: f32,
    /// Step classification.
    pub step_type: StepType,
    /// Accumulated episodic return (the paper's `info` dictionary keeps
    /// accumulations; we surface the one every experiment needs).
    pub episodic_return: f32,
}

impl Timestep {
    /// The timestep produced by `reset`.
    pub fn first() -> Timestep {
        Timestep {
            t: 0,
            action: -1,
            reward: 0.0,
            discount: 1.0,
            step_type: StepType::First,
            episodic_return: 0.0,
        }
    }

    #[inline]
    pub fn is_last(&self) -> bool {
        self.step_type.is_last()
    }
}

/// Batched per-env timestep metadata written by the batched stepper.
#[derive(Clone, Debug)]
pub struct BatchedTimestep {
    pub b: usize,
    pub t: Vec<u32>,
    pub action: Vec<i32>,
    pub reward: Vec<f32>,
    pub discount: Vec<f32>,
    pub step_type: Vec<StepType>,
    pub episodic_return: Vec<f32>,
}

impl BatchedTimestep {
    pub fn first(b: usize) -> BatchedTimestep {
        BatchedTimestep {
            b,
            t: vec![0; b],
            action: vec![-1; b],
            reward: vec![0.0; b],
            discount: vec![1.0; b],
            step_type: vec![StepType::First; b],
            episodic_return: vec![0.0; b],
        }
    }

    /// Scalar view of env `i`.
    pub fn get(&self, i: usize) -> Timestep {
        Timestep {
            t: self.t[i],
            action: self.action[i],
            reward: self.reward[i],
            discount: self.discount[i],
            step_type: self.step_type[i],
            episodic_return: self.episodic_return[i],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_timestep_padding_convention() {
        let ts = Timestep::first();
        assert_eq!(ts.action, -1, "paper pads the first action with -1");
        assert_eq!(ts.reward, 0.0, "paper pads the first reward with 0");
        assert_eq!(ts.step_type, StepType::First);
        assert!(!ts.is_last());
    }

    #[test]
    fn last_classification() {
        assert!(StepType::Terminated.is_last());
        assert!(StepType::Truncated.is_last());
        assert!(!StepType::First.is_last());
        assert!(!StepType::Mid.is_last());
    }

    #[test]
    fn batched_first() {
        let ts = BatchedTimestep::first(4);
        assert_eq!(ts.b, 4);
        assert!(ts.step_type.iter().all(|&s| s == StepType::First));
        let s0 = ts.get(0);
        assert_eq!(s0.action, -1);
    }
}
