//! Minimal configuration-file parser (serde is not vendored offline).
//!
//! Supports the INI-like subset the launcher needs: `key = value` pairs,
//! `[section]` headers, `#`/`;` comments, strings, ints, floats and bools.
//! Used by `navix train --config <file>` to describe experiments the same
//! way Rejax's YAML configs do for the paper's baselines (Table 9).

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// A parsed config: `section.key → value` (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split(['#', ';']).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(anyhow!("line {}: unterminated section header", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key {key}={v} not a usize")),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key {key}={v} not a float")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key {key}={v} not a u64")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow!("config key {key}={v} not a bool")),
        }
    }

    /// All keys under a section prefix.
    pub fn section(&self, name: &str) -> impl Iterator<Item = (&str, &str)> {
        let prefix = format!("{name}.");
        self.values.iter().filter_map(move |(k, v)| {
            k.strip_prefix(&prefix).map(|suffix| (suffix, v.as_str()))
        })
    }
}

/// Execution-layer configuration for the sharded multi-core stepper
/// ([`crate::batch::ShardedEnv`], the `pmap` analog): how many contiguous
/// shards a batch is split into and how many persistent worker threads step
/// them. `0` means "use the host's available parallelism" — the default.
/// `pipeline` additionally runs the stepper behind the double-buffered
/// rollout pipeline ([`crate::batch::PipelinedEnv`]), overlapping env
/// stepping with learner compute (bit-identical trajectories).
///
/// Sources: the `[parallel]` config-file section ([`ExecConfig::from_config`])
/// or the `--shards` / `--threads` / `--pipeline` command-line flags
/// ([`crate::cli::Args::exec_config`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecConfig {
    /// Number of contiguous shards (0 = auto).
    pub num_shards: usize,
    /// Number of worker threads (0 = auto, clamped to `num_shards`).
    pub num_threads: usize,
    /// Run the stepper behind the double-buffered rollout pipeline.
    pub pipeline: bool,
}

impl ExecConfig {
    /// Read `[parallel] num_shards / num_threads / pipeline` from a config
    /// file.
    pub fn from_config(cfg: &Config) -> Result<ExecConfig> {
        Ok(ExecConfig {
            num_shards: cfg.get_usize("parallel.num_shards", 0)?,
            num_threads: cfg.get_usize("parallel.num_threads", 0)?,
            pipeline: cfg.get_bool("parallel.pipeline", false)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
env = Navix-Empty-8x8-v0
seeds = 5

[ppo]
lr = 2.5e-4
num_envs = 16
anneal = true   ; trailing comment
name = "tuned"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("env"), Some("Navix-Empty-8x8-v0"));
        assert_eq!(c.get_usize("seeds", 0).unwrap(), 5);
        assert!((c.get_f32("ppo.lr", 0.0).unwrap() - 2.5e-4).abs() < 1e-9);
        assert_eq!(c.get_usize("ppo.num_envs", 0).unwrap(), 16);
        assert!(c.get_bool("ppo.anneal", false).unwrap());
        assert_eq!(c.get("ppo.name"), Some("tuned"));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("nope", 7).unwrap(), 7);
        assert!(!c.get_bool("nope", false).unwrap());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("k = x").unwrap().get_usize("k", 0).is_err());
    }

    #[test]
    fn section_iteration() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys: Vec<&str> = c.section("ppo").map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["anneal", "lr", "name", "num_envs"]);
    }

    #[test]
    fn exec_config_parses_parallel_section_and_defaults_to_auto() {
        let c =
            Config::parse("[parallel]\nnum_shards = 4\nnum_threads = 2\npipeline = true\n")
                .unwrap();
        let e = ExecConfig::from_config(&c).unwrap();
        assert_eq!(e, ExecConfig { num_shards: 4, num_threads: 2, pipeline: true });
        let none = ExecConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(none, ExecConfig::default());
        assert_eq!(none.num_shards, 0, "0 = auto");
        assert!(!none.pipeline, "pipeline is opt-in");
    }
}
