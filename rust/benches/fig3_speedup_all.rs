//! Paper Fig. 3: speedup of NAVIX vs. MiniGrid for all 30 Table-7
//! environments (x-ticks 0–29), 1K steps × 8 envs, 5 runs with 5–95 pct CI.
//! `NAVIX_BENCH_FAST=1` trims the protocol.

use navix::bench_harness::{bench, simd_meta, Report};
use navix::coordinator::{unroll_walltime, Engine};
use navix::envs::registry::fig3_envs;

fn main() {
    let fast = std::env::var("NAVIX_BENCH_FAST").is_ok();
    let (steps, runs, n_envs) = if fast { (50, 1, 4) } else { (1000, 5, 8) };

    let mut report = Report::new(
        "fig3_speedup_all",
        &["xtick", "env", "navix_median", "minigrid_median", "speedup"],
    );
    report.meta("agents_per_slot", "1");
    simd_meta(&mut report);
    for (xtick, env_id) in fig3_envs().into_iter().enumerate() {
        let navix = bench(if fast { 0 } else { 1 }, runs, || {
            unroll_walltime(Engine::Batched, env_id, n_envs, steps, 0).unwrap();
        });
        let baseline = bench(if fast { 0 } else { 1 }, runs, || {
            unroll_walltime(Engine::BaselineAsync, env_id, n_envs, steps, 0).unwrap();
        });
        report.row(&[
            xtick.to_string(),
            env_id.to_string(),
            navix.fmt_secs(),
            baseline.fmt_secs(),
            format!("{:.1}x", baseline.median / navix.median),
        ]);
    }
    report.save();
}
