//! Paper Fig. 5: wall time of 1K unrolls as the number of parallel
//! environments grows. The paper's MiniGrid baseline dies at 16 envs
//! (multiprocessing + RAM); NAVIX runs up to 2²¹ envs with near-flat wall
//! time. Here the batched engine sweeps to `NAVIX_FIG5_MAX` (default 2¹⁶)
//! and the thread-per-env baseline is capped at 256 workers.

use navix::bench_harness::{simd_meta, time_once, Report};
use navix::coordinator::{unroll_walltime, Engine};

fn main() {
    // --smoke: the CI bench-smoke profile (tiny batch, 1 iteration) whose
    // only purpose is recording `results/BENCH_fig5_batch.json` every run.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let fast = smoke || std::env::var("NAVIX_BENCH_FAST").is_ok();
    let max_batched: usize = std::env::var("NAVIX_FIG5_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke {
            64
        } else if fast {
            256
        } else {
            1 << 16
        });
    let max_async = if smoke { 4 } else if fast { 16 } else { 256 };
    let steps = if smoke { 5 } else if fast { 50 } else { 1000 };
    let env_id = "Navix-Empty-8x8-v0";

    let mut report =
        Report::new("fig5_batch", &["envs", "engine", "wall_s", "steps_per_s"]);
    report.meta("agents_per_slot", "1");
    simd_meta(&mut report);
    let mut b = 1usize;
    while b <= max_batched {
        let (secs, _) = time_once(|| {
            unroll_walltime(Engine::Batched, env_id, b, steps, 0).unwrap()
        });
        let _ = secs;
        let secs = unroll_walltime(Engine::Batched, env_id, b, steps, 0).unwrap();
        report.row(&[
            b.to_string(),
            "navix-batched".into(),
            format!("{secs:.4}"),
            format!("{:.0}", (b * steps) as f64 / secs),
        ]);
        if b <= max_async {
            for engine in [Engine::BaselineSync, Engine::BaselineAsync] {
                let secs = unroll_walltime(engine, env_id, b, steps, 0).unwrap();
                report.row(&[
                    b.to_string(),
                    engine.name().into(),
                    format!("{secs:.4}"),
                    format!("{:.0}", (b * steps) as f64 / secs),
                ]);
            }
        }
        b *= 4;
    }
    report.save();
    println!("\n(paper Fig. 5 shape: baseline throughput saturates while batched keeps");
    println!(" scaling until memory bandwidth; the async baseline's per-step barrier");
    println!(" is the multiprocessing overhead the paper measures)");
}
