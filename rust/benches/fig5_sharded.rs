//! Fig. 5, sharded: the multi-core batch stepper (`pmap` analog) vs. the
//! single-threaded batched engine (`vmap` analog) as the number of parallel
//! environments grows. Reports steps/s, the speedup over single-threaded,
//! and the per-shard load-imbalance ratio (max busy / mean busy).
//!
//! Both engines execute bit-identical work (same action stream, same RNG
//! contract — see `rust/src/batch/sharded.rs`), so the ratio is pure
//! execution-layer speedup. Expected shape on an `N`-core host: ≈1x at tiny
//! batches (synchronisation dominates), approaching `N`x by batch ≥ 1024.
//!
//! Scan-mode rows (`navix-batched-scan`, `navix-sharded-scan`): the same
//! action stream executed through the fused K-step `step_n` path
//! ([`navix::batch::rollout_random_scan`], window = 32), so the table shows
//! what rollout fusion buys each engine — for the sharded engine this is
//! one epoch/condvar round-trip per window instead of per step.
//!
//! Agent-axis rows (`agents` ∈ {1, 2, 4}): the same slot count with A
//! agents per slot, reported in agent-rows/s — the multi-agent scaling
//! surface of the `[B × A]` engine contract.
//!
//! `--smoke` (or `NAVIX_BENCH_FAST=1`): tiny batch, 1 iteration — the CI
//! bench-smoke job runs this and uploads `results/BENCH_fig5_sharded.json`.

use navix::batch::{rollout_random_scan, BatchedEnv, FaultPolicy, FaultStats, ShardedEnv};
use navix::bench_harness::{simd_meta, stats, ChaosInjector, Report};
use navix::rng::Key;
use std::time::Instant;

/// Fused-window size for the `*-scan` rows: long enough to amortise the
/// per-window sync, short enough that smoke runs still exercise >1 window.
const SCAN_WINDOW: usize = 32;

fn main() {
    let smoke =
        std::env::args().any(|a| a == "--smoke") || std::env::var("NAVIX_BENCH_FAST").is_ok();
    let env_id = "Navix-Empty-8x8-v0";
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let batches: Vec<usize> = if smoke { vec![64] } else { vec![256, 1024, 4096, 16384] };
    let steps = if smoke { 2 } else { 200 };

    let mut report = Report::new(
        "fig5_sharded",
        &[
            "envs", "agents", "engine", "shards", "threads", "wall_s", "agent_steps_per_s",
            "speedup", "imbalance",
        ],
    );
    report.meta("agents_per_slot", "1,2,4");
    simd_meta(&mut report);
    // Chaos-aware: with NAVIX_CHAOS exported every engine self-arms, so
    // quarantine the injected faults instead of dying and surface the
    // injected/recovered counters into the JSON meta block either way
    // (0/0 on a clean run) — the nightly trend can track recovery
    // overhead next to the throughput it costs.
    let chaos_armed = ChaosInjector::from_env().is_some();
    let mut faults = FaultStats::default();
    for &b in &batches {
        let cfg = navix::make(env_id).unwrap();

        let mut single = BatchedEnv::new(cfg.clone(), b, Key::new(0));
        if chaos_armed {
            single.supervise(FaultPolicy::QuarantineSlot);
        }
        let t0 = Instant::now();
        single.rollout_random(steps, 0xAC7);
        let base_secs = t0.elapsed().as_secs_f64();
        faults.merge(single.fault_stats());
        report.row(&[
            b.to_string(),
            "1".into(),
            "navix-batched".into(),
            "1".into(),
            "1".into(),
            format!("{base_secs:.4}"),
            format!("{:.0}", (b * steps) as f64 / base_secs),
            "1.00x".into(),
            "-".into(),
        ]);

        // Scan mode, same engine: fused K-step windows through step_n.
        let mut single = BatchedEnv::new(cfg.clone(), b, Key::new(0));
        if chaos_armed {
            single.supervise(FaultPolicy::QuarantineSlot);
        }
        let t0 = Instant::now();
        rollout_random_scan(&mut single, steps, 0xAC7, SCAN_WINDOW);
        let scan_secs = t0.elapsed().as_secs_f64();
        faults.merge(single.fault_stats());
        report.row(&[
            b.to_string(),
            "1".into(),
            "navix-batched-scan".into(),
            "1".into(),
            "1".into(),
            format!("{scan_secs:.4}"),
            format!("{:.0}", (b * steps) as f64 / scan_secs),
            format!("{:.2}x", base_secs / scan_secs),
            "-".into(),
        ]);

        // One shard per thread, then 2 shards per thread (finer shards
        // smooth load imbalance at the cost of more lock traffic).
        for shards in [threads, 2 * threads] {
            let mut env = ShardedEnv::new(cfg.clone(), b, shards, threads, Key::new(0));
            if chaos_armed {
                env.supervise(FaultPolicy::QuarantineSlot);
            }
            let t0 = Instant::now();
            env.rollout_random(steps, 0xAC7);
            let secs = t0.elapsed().as_secs_f64();
            faults.merge(env.fault_stats());
            let busy = env.shard_busy_secs();
            report.row(&[
                b.to_string(),
                "1".into(),
                "navix-sharded".into(),
                env.num_shards.to_string(),
                env.num_threads.to_string(),
                format!("{secs:.4}"),
                format!("{:.0}", (b * steps) as f64 / secs),
                format!("{:.2}x", base_secs / secs),
                format!("{:.2}", stats::imbalance(&busy)),
            ]);

            // Same shard geometry, fused windows: one epoch/condvar
            // round-trip per SCAN_WINDOW steps instead of per step.
            let mut env = ShardedEnv::new(cfg.clone(), b, shards, threads, Key::new(0));
            if chaos_armed {
                env.supervise(FaultPolicy::QuarantineSlot);
            }
            let t0 = Instant::now();
            rollout_random_scan(&mut env, steps, 0xAC7, SCAN_WINDOW);
            let secs = t0.elapsed().as_secs_f64();
            faults.merge(env.fault_stats());
            let busy = env.shard_busy_secs();
            report.row(&[
                b.to_string(),
                "1".into(),
                "navix-sharded-scan".into(),
                env.num_shards.to_string(),
                env.num_threads.to_string(),
                format!("{secs:.4}"),
                format!("{:.0}", (b * steps) as f64 / secs),
                format!("{:.2}x", base_secs / secs),
                format!("{:.2}", stats::imbalance(&busy)),
            ]);
        }
    }

    // Agent-axis rows: the same slot count with A ∈ {1, 2, 4} agents per
    // slot. Throughput is agent-rows/s (b·a rows advance per step), so
    // perfect scaling along the agent axis shows as a near-flat
    // `agent_steps_per_s` column.
    let ab = if smoke { 64 } else { 1024 };
    let mut a1_secs = f64::NAN;
    for a in [1usize, 2, 4] {
        let cfg = navix::make(env_id).unwrap().with_agents(a);

        let mut single = BatchedEnv::new(cfg.clone(), ab, Key::new(0));
        if chaos_armed {
            single.supervise(FaultPolicy::QuarantineSlot);
        }
        let t0 = Instant::now();
        single.rollout_random(steps, 0xAC7);
        let secs = t0.elapsed().as_secs_f64();
        faults.merge(single.fault_stats());
        if a == 1 {
            a1_secs = secs;
        }
        report.row(&[
            ab.to_string(),
            a.to_string(),
            "navix-batched".into(),
            "1".into(),
            "1".into(),
            format!("{secs:.4}"),
            format!("{:.0}", (ab * a * steps) as f64 / secs),
            format!("{:.2}x", a1_secs / secs),
            "-".into(),
        ]);

        let mut env = ShardedEnv::new(cfg, ab, threads, threads, Key::new(0));
        if chaos_armed {
            env.supervise(FaultPolicy::QuarantineSlot);
        }
        let t0 = Instant::now();
        env.rollout_random(steps, 0xAC7);
        let secs = t0.elapsed().as_secs_f64();
        faults.merge(env.fault_stats());
        let busy = env.shard_busy_secs();
        report.row(&[
            ab.to_string(),
            a.to_string(),
            "navix-sharded".into(),
            env.num_shards.to_string(),
            env.num_threads.to_string(),
            format!("{secs:.4}"),
            format!("{:.0}", (ab * a * steps) as f64 / secs),
            format!("{:.2}x", a1_secs / secs),
            format!("{:.2}", stats::imbalance(&busy)),
        ]);
    }
    report.meta("faults_injected", &faults.injected.to_string());
    report.meta("faults_recovered", &faults.recovered.to_string());
    report.save();
    println!("\n(pmap-analog shape: sharded ≈ 1x at tiny batches — the epoch barrier");
    println!(" dominates — and approaches the core count once per-step work amortises");
    println!(" it; imbalance explains any residual gap to the thread count)");
}
