//! Observation-path throughput: the packed cell-code overlay grid (+
//! dirty-tile rgb, + SIMD streaming featurisers) vs. the original naive
//! entity-table scans, measured as end-to-end batched stepping (steps/s
//! through `BatchedEnv::step`, random actions, autoresets included) — all
//! paths execute bit-identical trajectories (`tests/test_obs_parity.rs`),
//! so the ratios are pure observation-layer speedup.
//!
//! Three columns per cell: `naive_sps` (scan oracle), `scalar_sps` (the
//! overlay path forced to `KernelPath::Scalar`) and `simd_sps` (the
//! overlay path on the auto-detected kernel). `simd_mult` =
//! simd/scalar — the vector multiple on the full-grid i32 kinds;
//! first-person and rgb kinds run the same code on every kernel path, so
//! their multiple sits at ~1× by construction. `total_mult` = simd/naive.
//!
//! Grid: all six observation kinds × {Empty-16x16, DoorKey-16x16,
//! LockedRoom, Dynamic-Obstacles-16x16, GoToObj-8x8-N3 (mission
//! featurisation overhead), Curriculum-RoomGrid (2-clause tokenised
//! missions + per-episode difficulty draw)} × B ∈ {256, 2048} (rgb kinds use
//! smaller batches — a 2048-env 512×512×3 rgb buffer alone is 1.6 GB).
//! Emits `results/BENCH_obs.json` via the bench_harness JSON writer; the
//! `meta` block records the SIMD dispatch decision (`simd_path` etc. —
//! see `bench_harness::simd_meta`). Methodology and recorded numbers live
//! in `EXPERIMENTS.md` §Perf and §SIMD.
//!
//! `--smoke` (or `NAVIX_BENCH_FAST=1`): tiny batch, few steps — the CI
//! bench-smoke job runs this, uploads the JSON artifact, and **fails
//! loudly** if the overlay path's steps/s (the min over the full-grid
//! symbolic and first-person-symbolic smoke cells, on the active kernel)
//! drops below the recorded floor (`[obs]` in `bench_floors.toml`,
//! overridable via `NAVIX_OBS_SMOKE_FLOOR`). On a miss the bench exits
//! non-zero after printing one `measured … < floor …` line — naming the
//! active kernel path, so a scalar-fallback regression is diagnosable
//! from that line alone — and recording everything in the JSON's `meta`.

use navix::batch::BatchedEnv;
use navix::bench_harness::{floors, simd_meta, Report};
use navix::core::mission::MISSION_TOKENS;
use navix::rng::Key;
use navix::simd::{self, KernelPath};
use navix::systems::observations::{ObsKind, ObsRoute};
use std::time::Instant;

const ENV_IDS: [&str; 6] = [
    "Navix-Empty-16x16-v0",
    "Navix-DoorKey-16x16-v0",
    "Navix-LockedRoom-v0",
    "Navix-Dynamic-Obstacles-16x16",
    // Goal-conditioned family: tracks the mission-featurisation overhead
    // (the per-step MISSION_TOKENS token-slab write) in BENCH_obs.json.
    "Navix-GoToObj-8x8-N3-v0",
    // Sequenced/curriculum family: 2-clause tokenised missions plus the
    // per-episode difficulty draw and satisfiability-gated resets.
    "Navix-Curriculum-RoomGrid-v0",
];

const KINDS: [ObsKind; 6] = [
    ObsKind::Symbolic,
    ObsKind::SymbolicFirstPerson,
    ObsKind::Categorical,
    ObsKind::CategoricalFirstPerson,
    ObsKind::Rgb,
    ObsKind::RgbFirstPerson,
];

/// Width of the tokenised-mission block this env streams per agent-row
/// per step: `MISSION_TOKENS` for mission families, 0 for goal-only ones
/// (the observation layer skips the write entirely).
fn mission_width(id: &str) -> usize {
    let cfg = navix::make(id).unwrap();
    let env = BatchedEnv::new(cfg, 1, Key::new(0));
    if env.obs.mission.iter().any(|&x| x != 0) {
        MISSION_TOKENS
    } else {
        0
    }
}

/// End-to-end steps/s of one (env, kind, route) cell.
fn steps_per_s(id: &str, kind: ObsKind, b: usize, steps: usize, route: ObsRoute) -> f64 {
    let cfg = navix::make(id).unwrap().with_observation(kind);
    let mut env = BatchedEnv::new(cfg, b, Key::new(0));
    env.set_obs_route(route);
    let t0 = Instant::now();
    env.rollout_random(steps, 0x0B5);
    (b * steps) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke =
        std::env::args().any(|a| a == "--smoke") || std::env::var("NAVIX_BENCH_FAST").is_ok();
    // Smoke keeps Empty + DoorKey plus one single-clause and one sequenced
    // mission family, so the CI floor gate also times the goal-conditioning
    // token-slab write and the curriculum's gated resets.
    let ids: &[&str] = if smoke {
        &[
            "Navix-Empty-16x16-v0",
            "Navix-DoorKey-16x16-v0",
            "Navix-GoToObj-8x8-N3-v0",
            "Navix-Curriculum-RoomGrid-v0",
        ]
    } else {
        &ENV_IDS
    };
    let kinds: &[ObsKind] = if smoke {
        &[ObsKind::Symbolic, ObsKind::SymbolicFirstPerson, ObsKind::Rgb]
    } else {
        &KINDS
    };

    let mut report = Report::new(
        "obs",
        &[
            "env",
            "obs",
            "mission_toks",
            "envs",
            "steps",
            "naive_sps",
            "scalar_sps",
            "simd_sps",
            "simd_mult",
            "total_mult",
        ],
    );
    let active = simd::active();
    let mut smoke_floor_sps = f64::INFINITY;
    for &id in ids {
        let m_toks = mission_width(id);
        for &kind in kinds {
            // Rgb buffers are 3 KB/tile: cap the batch so the full sweep
            // stays in memory (Empty-16x16 rgb at B=2048 would be 1.6 GB).
            // Smoke keeps enough work (64×50 env-steps for i32 kinds) that
            // the floor assertion times real compute, not timer noise.
            let batches: Vec<usize> = match (smoke, kind.is_rgb()) {
                (true, false) => vec![64],
                (true, true) => vec![16],
                (false, false) => vec![256, 2048],
                (false, true) => vec![16, 64],
            };
            let steps = match (smoke, kind.is_rgb()) {
                (true, false) => 50,
                (true, true) => 4,
                (false, false) => 100,
                (false, true) => 20,
            };
            for &b in &batches {
                let naive = steps_per_s(id, kind, b, steps, ObsRoute::Scan);
                let scalar =
                    steps_per_s(id, kind, b, steps, ObsRoute::Overlay(KernelPath::Scalar));
                let vec_sps = steps_per_s(id, kind, b, steps, ObsRoute::Overlay(active));
                // Gate on what the SIMD work accelerates (full-grid
                // symbolic) AND the historical first-person cell, both on
                // the active kernel — min of the two feeds the floor.
                if matches!(kind, ObsKind::Symbolic | ObsKind::SymbolicFirstPerson) {
                    smoke_floor_sps = smoke_floor_sps.min(vec_sps);
                }
                report.row(&[
                    id.to_string(),
                    kind.name().to_string(),
                    m_toks.to_string(),
                    b.to_string(),
                    steps.to_string(),
                    format!("{naive:.0}"),
                    format!("{scalar:.0}"),
                    format!("{vec_sps:.0}"),
                    format!("{:.2}x", vec_sps / scalar),
                    format!("{:.2}x", vec_sps / naive),
                ]);
            }
        }
    }
    if smoke {
        // Regression gate: the overlay path must clear the recorded floor
        // (committed in bench_floors.toml; see that file for the rationale
        // behind the margin). Gate + measurement + kernel path land in the
        // JSON's meta so the uploaded artifact is self-describing even on
        // a miss.
        let floor = floors::resolve("obs", "NAVIX_OBS_SMOKE_FLOOR", 100_000.0);
        report.meta("agents_per_slot", "1");
        report.meta("curriculum", "Navix-Curriculum-RoomGrid-v0");
        report.meta("gate", "overlay symbolic + symbolic_first_person steps/s (active kernel)");
        report.meta("measured", &format!("{smoke_floor_sps:.0}"));
        report.meta("floor", &format!("{:.0}", floor.value));
        report.meta("floor_source", &floor.source);
        simd_meta(&mut report);
        report.save();
        if smoke_floor_sps < floor.value {
            println!(
                "measured {smoke_floor_sps:.0} steps/s < floor {:.0} (source: {}) \
                 [kernel path: {}, detected: {}]",
                floor.value,
                floor.source,
                active.name(),
                simd::detected().name()
            );
            std::process::exit(1);
        }
        println!(
            "\nsmoke gate: overlay symbolic kinds ≥ {:.0} steps/s \
             (measured {smoke_floor_sps:.0}, source: {}, kernel path: {}) — OK",
            floor.value,
            floor.source,
            active.name()
        );
    } else {
        report.meta("curriculum", "Navix-Curriculum-RoomGrid-v0");
        simd_meta(&mut report);
        report.save();
        println!("\n(expected shape: simd ≥1.5x scalar on full-grid symbolic at B=2048 —");
        println!(" first-person and rgb rows sit at ~1x simd_mult by construction; overlay");
        println!(" beats naive everywhere — the naive path paid O(caps) per cell — and");
        println!(" full rgb gains most: dirty tiles re-blit only what changed)");
    }
}
