//! Observation-path throughput: the packed cell-code overlay grid (+
//! dirty-tile rgb) vs. the original naive entity-table scans, measured as
//! end-to-end batched stepping (steps/s through `BatchedEnv::step`, random
//! actions, autoresets included) — the two paths execute bit-identical
//! trajectories (`tests/test_obs_parity.rs`), so the ratio is pure
//! observation-layer speedup.
//!
//! Grid: all six observation kinds × {Empty-16x16, DoorKey-16x16,
//! LockedRoom, Dynamic-Obstacles-16x16, GoToObj-8x8-N3 (mission
//! featurisation overhead)} × B ∈ {256, 2048} (rgb kinds use
//! smaller batches — a 2048-env 512×512×3 rgb buffer alone is 1.6 GB).
//! Emits `results/BENCH_obs.json` via the bench_harness JSON writer;
//! methodology and recorded numbers live in `EXPERIMENTS.md` §Perf.
//!
//! `--smoke` (or `NAVIX_BENCH_FAST=1`): tiny batch, few steps — the CI
//! bench-smoke job runs this, uploads the JSON artifact, and **fails
//! loudly** if the overlay path's first-person-symbolic steps/s drops
//! below the recorded floor (`[obs]` in `bench_floors.toml`, overridable
//! via `NAVIX_OBS_SMOKE_FLOOR`). On a miss the bench exits non-zero after
//! printing one `measured … < floor …` line and recording both values in
//! the JSON's `meta` — no panic backtrace for CI logs to truncate.

use navix::batch::BatchedEnv;
use navix::bench_harness::{floors, Report};
use navix::rng::Key;
use navix::systems::observations::{ObsKind, ObsPath};
use std::time::Instant;

const ENV_IDS: [&str; 5] = [
    "Navix-Empty-16x16-v0",
    "Navix-DoorKey-16x16-v0",
    "Navix-LockedRoom-v0",
    "Navix-Dynamic-Obstacles-16x16",
    // Goal-conditioned family: tracks the mission-featurisation overhead
    // (the per-step MISSION_DIM write) in BENCH_obs.json.
    "Navix-GoToObj-8x8-N3-v0",
];

const KINDS: [ObsKind; 6] = [
    ObsKind::Symbolic,
    ObsKind::SymbolicFirstPerson,
    ObsKind::Categorical,
    ObsKind::CategoricalFirstPerson,
    ObsKind::Rgb,
    ObsKind::RgbFirstPerson,
];

/// End-to-end steps/s of one (env, kind, path) cell.
fn steps_per_s(id: &str, kind: ObsKind, b: usize, steps: usize, path: ObsPath) -> f64 {
    let cfg = navix::make(id).unwrap().with_observation(kind);
    let mut env = BatchedEnv::new(cfg, b, Key::new(0));
    env.set_obs_path(path);
    let t0 = Instant::now();
    env.rollout_random(steps, 0x0B5);
    (b * steps) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke =
        std::env::args().any(|a| a == "--smoke") || std::env::var("NAVIX_BENCH_FAST").is_ok();
    // Smoke keeps Empty + DoorKey and one mission family, so the CI floor
    // gate also times the goal-conditioning write.
    let ids: &[&str] = if smoke {
        &["Navix-Empty-16x16-v0", "Navix-DoorKey-16x16-v0", "Navix-GoToObj-8x8-N3-v0"]
    } else {
        &ENV_IDS
    };
    let kinds: &[ObsKind] = if smoke {
        &[ObsKind::Symbolic, ObsKind::SymbolicFirstPerson, ObsKind::Rgb]
    } else {
        &KINDS
    };

    let mut report = Report::new(
        "obs",
        &["env", "obs", "envs", "steps", "naive_sps", "overlay_sps", "speedup"],
    );
    let mut smoke_floor_sps = f64::INFINITY;
    for &id in ids {
        for &kind in kinds {
            // Rgb buffers are 3 KB/tile: cap the batch so the full sweep
            // stays in memory (Empty-16x16 rgb at B=2048 would be 1.6 GB).
            // Smoke keeps enough work (64×50 env-steps for i32 kinds) that
            // the floor assertion times real compute, not timer noise.
            let batches: Vec<usize> = match (smoke, kind.is_rgb()) {
                (true, false) => vec![64],
                (true, true) => vec![16],
                (false, false) => vec![256, 2048],
                (false, true) => vec![16, 64],
            };
            let steps = match (smoke, kind.is_rgb()) {
                (true, false) => 50,
                (true, true) => 4,
                (false, false) => 100,
                (false, true) => 20,
            };
            for &b in &batches {
                let naive = steps_per_s(id, kind, b, steps, ObsPath::NaiveScan);
                let overlay = steps_per_s(id, kind, b, steps, ObsPath::Overlay);
                if kind == ObsKind::SymbolicFirstPerson {
                    smoke_floor_sps = smoke_floor_sps.min(overlay);
                }
                report.row(&[
                    id.to_string(),
                    kind.name().to_string(),
                    b.to_string(),
                    steps.to_string(),
                    format!("{naive:.0}"),
                    format!("{overlay:.0}"),
                    format!("{:.2}x", overlay / naive),
                ]);
            }
        }
    }
    if smoke {
        // Regression gate: the overlay path must clear the recorded floor
        // (committed in bench_floors.toml; see that file for the rationale
        // behind the margin). Gate + measurement land in the JSON's meta so
        // the uploaded artifact is self-describing even on a miss.
        let floor = floors::resolve("obs", "NAVIX_OBS_SMOKE_FLOOR", 100_000.0);
        report.meta("agents_per_slot", "1");
        report.meta("gate", "overlay symbolic_first_person steps/s");
        report.meta("measured", &format!("{smoke_floor_sps:.0}"));
        report.meta("floor", &format!("{:.0}", floor.value));
        report.meta("floor_source", &floor.source);
        report.save();
        if smoke_floor_sps < floor.value {
            println!(
                "measured {smoke_floor_sps:.0} steps/s < floor {:.0} (source: {})",
                floor.value, floor.source
            );
            std::process::exit(1);
        }
        println!(
            "\nsmoke gate: overlay symbolic_first_person ≥ {:.0} steps/s \
             (measured {smoke_floor_sps:.0}, source: {}) — OK",
            floor.value, floor.source
        );
    } else {
        report.save();
        println!("\n(expected shape: overlay ≥2x naive on first-person symbolic at B=2048;");
        println!(" full-grid kinds gain more — the naive path paid O(caps) per cell — and");
        println!(" full rgb gains most: dirty tiles re-blit only what changed)");
    }
}
