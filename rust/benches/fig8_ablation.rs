//! Paper Fig. 8 (Appendix E): **ablation — speedup without batching.**
//! Runs every Table-7 environment at batch size 1 for both engines. The
//! paper's conclusion: most of NAVIX's win comes from batching; unbatched,
//! the speedup shrinks dramatically. Here the analogous ablation compares
//! the SoA engine at B=1 with the scalar OO baseline — isolating the
//! data-layout/dispatch component from the batching component (read
//! together with fig3's batched numbers).

use navix::bench_harness::{bench, simd_meta, Report};
use navix::coordinator::{unroll_walltime, Engine};
use navix::envs::registry::fig3_envs;

fn main() {
    let fast = std::env::var("NAVIX_BENCH_FAST").is_ok();
    let (steps, runs) = if fast { (50, 1) } else { (1000, 5) };

    let mut report = Report::new(
        "fig8_ablation_nobatch",
        &["xtick", "env", "navix_b1_median", "minigrid_b1_median", "speedup"],
    );
    report.meta("agents_per_slot", "1");
    simd_meta(&mut report);
    for (xtick, env_id) in fig3_envs().into_iter().enumerate() {
        let navix = bench(0, runs, || {
            unroll_walltime(Engine::Batched, env_id, 1, steps, 0).unwrap();
        });
        let baseline = bench(0, runs, || {
            unroll_walltime(Engine::BaselineSync, env_id, 1, steps, 0).unwrap();
        });
        report.row(&[
            xtick.to_string(),
            env_id.to_string(),
            navix.fmt_secs(),
            baseline.fmt_secs(),
            format!("{:.2}x", baseline.median / navix.median),
        ]);
    }
    report.save();
    println!("\n(paper Fig. 8: without batching the speedup collapses — compare these");
    println!(" ratios against fig3's batched ones to see batching dominate)");
}
