//! Paper Fig. 1: speedups for five headline environments vs. the MiniGrid
//! baseline. Protocol (§4.1): 1K steps × 8 parallel envs, 5 runs, 5–95 pct
//! CI. `NAVIX_BENCH_FAST=1` trims steps/runs for CI smoke.

use navix::bench_harness::{bench, simd_meta, Report};
use navix::coordinator::{unroll_walltime, Engine};

const FIG1_ENVS: [&str; 5] = [
    "Navix-Empty-8x8-v0",
    "Navix-DoorKey-8x8-v0",
    "Navix-Dynamic-Obstacles-8x8",
    "Navix-KeyCorridorS3R3-v0",
    "Navix-LavaGapS7-v0",
];

fn main() {
    let fast = std::env::var("NAVIX_BENCH_FAST").is_ok();
    let (steps, runs, n_envs) = if fast { (100, 2, 8) } else { (1000, 5, 8) };

    let mut report = Report::new(
        "fig1_speedup",
        &["env", "navix_median", "minigrid_median", "speedup"],
    );
    report.meta("agents_per_slot", "1");
    simd_meta(&mut report);
    for env_id in FIG1_ENVS {
        let navix = bench(1, runs, || {
            unroll_walltime(Engine::Batched, env_id, n_envs, steps, 0).unwrap();
        });
        let baseline = bench(1, runs, || {
            unroll_walltime(Engine::BaselineAsync, env_id, n_envs, steps, 0).unwrap();
        });
        report.row(&[
            env_id.to_string(),
            navix.fmt_secs(),
            baseline.fmt_secs(),
            format!("{:.1}x", baseline.median / navix.median),
        ]);
    }
    report.save();
    println!("\n(paper Fig. 1 shape: NAVIX below baseline on every env; see EXPERIMENTS.md)");
}
