//! Paper Fig. 6: computation cost of training N PPO agents in parallel
//! (each with 16 envs). The paper trains up to 2048 agents × 1M steps on an
//! A100 in <50s (≈670M steps/s); this testbed sweeps N ∈ {1,2,4,8} at
//! `NAVIX_FIG6_STEPS` steps each (default 8192) and reports the same
//! accounting, plus the MiniGrid-baseline comparison (a single PPO agent on
//! the thread-per-env vector baseline).
//!
//! Every run also emits the **training-throughput report**
//! (`results/BENCH_train.json`, same `{name, header, rows}` schema as
//! `BENCH_obs.json`): end-to-end PPO steps/s per execution mode — serial
//! batched, sharded, and the double-buffered pipeline (all collecting via
//! the fused `step_n` scan path since PR 6) — with the batch size, shard
//! count and commit recorded per row, plus a `rollout-scan` /
//! `rollout-stepwise` pair that times rollout collection alone so the
//! fused-dispatch gain is visible in isolation (EXPERIMENTS.md §"Scan
//! mode").
//!
//! `--smoke`: the CI train-smoke job's mode — small runs only, and the
//! build **fails** (single `measured … < floor …` line + non-zero exit;
//! gate values recorded in the JSON `meta`) if the best end-to-end mode's
//! steps/s drops below the recorded floor (`[train]` in
//! `bench_floors.toml`, overridable via `NAVIX_TRAIN_SMOKE_FLOOR`), so a
//! training hot-path regression (e.g. the batched GEMM degrading to
//! per-sample inference) cannot ship silently. `NAVIX_BENCH_FAST=1`
//! keeps the suite-wide convention: trimmed workload, full reports, no
//! assertion.

use navix::agents::ppo::{Ppo, PpoConfig, Rollout};
use navix::agents::{preprocess_obs, ReturnTracker};
use navix::baseline::AsyncVectorEnv;
use navix::batch::{BatchedEnv, FaultPolicy, FaultStats};
use navix::bench_harness::{floors, simd_meta, ChaosInjector, Report};
use navix::config::ExecConfig;
use navix::coordinator::multi_agent::{
    train_parallel_ppo, train_parallel_ppo_exec, MultiAgentResult,
};
use navix::nn::sample_categorical;
use navix::rng::Key;

/// Commit id for the BENCH_train.json rows: CI's GITHUB_SHA, an explicit
/// NAVIX_COMMIT, or a best-effort `git rev-parse` (offline-safe fallback:
/// "unknown").
fn commit_id() -> String {
    for var in ["NAVIX_COMMIT", "GITHUB_SHA"] {
        if let Ok(v) = std::env::var(var) {
            if !v.is_empty() {
                return v.chars().take(12).collect();
            }
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into())
}

struct TrainReport {
    report: Report,
    commit: String,
    best_sps: f64,
}

impl TrainReport {
    fn new() -> Self {
        TrainReport {
            report: Report::new(
                "train",
                &[
                    "mode",
                    "agents",
                    "envs_per_agent",
                    "total_envs",
                    "shards",
                    "steps",
                    "wall_s",
                    "steps_per_s",
                    "mean_return",
                    "commit",
                ],
            ),
            commit: commit_id(),
            best_sps: 0.0,
        }
    }

    fn row(&mut self, mode: &str, shards: &str, r: &MultiAgentResult) {
        self.best_sps = self.best_sps.max(r.steps_per_second);
        self.report.row(&[
            mode.to_string(),
            format!("{}", r.n_agents),
            format!("{}", r.envs_per_agent),
            format!("{}", r.n_agents * r.envs_per_agent),
            shards.to_string(),
            format!("{}", r.total_env_steps),
            format!("{:.2}", r.wall_secs),
            format!("{:.0}", r.steps_per_second),
            format!("{:.3}", r.mean_final_return),
            self.commit.clone(),
        ]);
    }
}

/// Steps/s of rollout *collection* alone (no learner update): the same PPO
/// policy network driving 16 envs, through either the fused one-`step_n`-
/// per-horizon path or the per-step oracle loop. Both produce bit-identical
/// trajectories (`fused_rollout_matches_the_stepwise_oracle`), so the delta
/// between the two BENCH_train.json rows is pure dispatch overhead.
fn rollout_sps(env_id: &str, fused: bool, steps: u64, faults: &mut FaultStats) -> f64 {
    let d = navix::agents::OBS_DIM;
    let mut env = BatchedEnv::new(navix::make(env_id).unwrap(), 16, Key::new(0));
    // With NAVIX_CHAOS exported the engine self-arms its injector:
    // quarantine the faults so the bench survives and the counters land
    // in the JSON meta (0/0 on a clean run).
    if ChaosInjector::from_env().is_some() {
        env.supervise(FaultPolicy::QuarantineSlot);
    }
    let mut ppo = Ppo::new(PpoConfig { num_envs: 16, ..PpoConfig::default() }, d, 7, 0);
    let mut ro = Rollout::new(ppo.cfg.rollout_len, 16, d);
    let mut tracker = ReturnTracker::new(64);
    let per_iter = (ppo.cfg.rollout_len * 16) as u64;
    let iters = steps.div_ceil(per_iter).max(1);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        if fused {
            ppo.collect_rollout(&mut env, &mut ro, &mut tracker);
        } else {
            ppo.collect_rollout_stepwise(&mut env, &mut ro, &mut tracker);
        }
    }
    let sps = (iters * per_iter) as f64 / t0.elapsed().as_secs_f64();
    faults.merge(env.fault_stats());
    sps
}

fn main() {
    // --smoke is the CI gate (small runs + hard floor assert); the
    // suite-wide NAVIX_BENCH_FAST convention only trims the workload and
    // never asserts.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let fast = smoke || std::env::var("NAVIX_BENCH_FAST").is_ok();
    let env_id = "Navix-Empty-8x8-v0";
    let steps: u64 = std::env::var("NAVIX_FIG6_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 4096 } else { 8192 });

    // --- Training-throughput report: serial vs sharded vs pipelined, one
    // agent × 16 envs (the unit every Fig.-6 point is built from).
    let mut train = TrainReport::new();
    let serial = train_parallel_ppo(env_id, 1, 16, steps, 0).unwrap();
    train.row("serial", "1", &serial);
    let sharded_exec = ExecConfig { pipeline: false, ..ExecConfig::default() };
    let sharded =
        train_parallel_ppo_exec(env_id, 1, 16, steps, 0, Some(sharded_exec)).unwrap();
    train.row("sharded", "auto", &sharded);
    let piped_exec = ExecConfig { pipeline: true, ..ExecConfig::default() };
    let piped = train_parallel_ppo_exec(env_id, 1, 16, steps, 0, Some(piped_exec)).unwrap();
    train.row("pipelined", "auto", &piped);

    // Scan-vs-stepwise microcomparison rows (collection only, no update).
    // Deliberately NOT routed through train.row: the floor gate judges
    // end-to-end training modes, not this microbenchmark.
    let mut faults = FaultStats::default();
    for (mode, fused) in [("rollout-scan", true), ("rollout-stepwise", false)] {
        let sps = rollout_sps(env_id, fused, steps, &mut faults);
        let commit = train.commit.clone();
        train.report.row(&[
            mode.to_string(),
            "1".into(),
            "16".into(),
            "16".into(),
            "1".into(),
            format!("{steps}"),
            "-".into(),
            format!("{sps:.0}"),
            "-".into(),
            commit,
        ]);
    }

    if smoke {
        // Regression gate: the best execution mode must clear the recorded
        // floor (committed in bench_floors.toml; see that file for the
        // margin rationale). Gate + measurement land in the JSON's meta so
        // the uploaded artifact is self-describing even on a miss.
        let floor = floors::resolve("train", "NAVIX_TRAIN_SMOKE_FLOOR", 5_000.0);
        train.report.meta("agents_per_slot", "1");
        train.report.meta("gate", "best end-to-end PPO mode steps/s");
        train.report.meta("measured", &format!("{:.0}", train.best_sps));
        train.report.meta("floor", &format!("{:.0}", floor.value));
        train.report.meta("floor_source", &floor.source);
        train.report.meta("faults_injected", &faults.injected.to_string());
        train.report.meta("faults_recovered", &faults.recovered.to_string());
        simd_meta(&mut train.report);
        train.report.save();
        if train.best_sps < floor.value {
            println!(
                "measured {:.0} steps/s < floor {:.0} (source: {}) \
                 [kernel path: {}, detected: {}]",
                train.best_sps,
                floor.value,
                floor.source,
                navix::simd::active().name(),
                navix::simd::detected().name()
            );
            std::process::exit(1);
        }
        println!(
            "\nsmoke gate: PPO training ≥ {:.0} steps/s (best mode measured {:.0}, \
             source: {}) — OK",
            floor.value, train.best_sps, floor.source
        );
        return;
    }

    let max_agents = if fast { 2 } else { 8 };
    let mut report = Report::new(
        "fig6_ppo_agents",
        &["agents", "total_envs", "wall_s", "steps_per_s", "mean_return"],
    );
    report.meta("agents_per_slot", "1");

    // NAVIX engine: N agents in one process.
    let mut n = 1usize;
    while n <= max_agents {
        let r = train_parallel_ppo(env_id, n, 16, steps, 0).unwrap();
        report.row(&[
            format!("{n}"),
            format!("{}", n * 16),
            format!("{:.2}", r.wall_secs),
            format!("{:.0}", r.steps_per_second),
            format!("{:.3}", r.mean_final_return),
        ]);
        n *= 2;
    }

    // MiniGrid baseline: ONE agent doing FULL PPO training on the
    // thread-per-env vector baseline (the paper's "original implementation
    // trains a single PPO agent") — rollouts through the OO engine +
    // channel barrier, identical learner.
    let cfg = navix::make(env_id).unwrap();
    let d = navix::agents::OBS_DIM;
    let mut venv = AsyncVectorEnv::new(cfg, 16, Key::new(0));
    let mut obs = venv.reset();
    let mut ppo = Ppo::new(PpoConfig::default(), d, 7, 0);
    let mut rng = navix::rng::Rng::new(1);
    let t_len = ppo.cfg.rollout_len;
    let mut ro = navix::agents::ppo::Rollout::new(t_len, 16, d);
    let mut x = vec![0.0f32; d];
    let start = std::time::Instant::now();
    let mut done_steps = 0u64;
    let mut lp = vec![0.0f32; 7];
    while done_steps < steps {
        for t in 0..t_len {
            let mut actions = vec![0u8; 16];
            for (i, o) in obs.iter().enumerate() {
                // The OO vector baseline returns grid-only observations
                // (the pre-mission API the paper benchmarks): featurise the
                // grid prefix; the OBS_DIM-wide buffer's mission tail was
                // allocated zero and is never written, so it stays zero.
                preprocess_obs(o, &mut x[..o.len()]);
                let logits = ppo.actor.infer(&x);
                let a = sample_categorical(&logits, &mut rng);
                navix::nn::log_softmax(&logits, &mut lp);
                let idx = t * 16 + i;
                ro.obs[idx * d..(idx + 1) * d].copy_from_slice(&x);
                ro.actions[idx] = a as u8;
                ro.logp[idx] = lp[a];
                ro.values[idx] = ppo.critic.infer(&x)[0];
                actions[i] = a as u8;
            }
            let r = venv.step(&actions);
            for i in 0..16 {
                let idx = t * 16 + i;
                ro.rewards[idx] = r.reward[i];
                ro.discounts[idx] = if r.terminated[i] { 0.0 } else { 1.0 };
                ro.boundaries[idx] = r.terminated[i] || r.truncated[i];
            }
            obs = r.obs;
            done_steps += 16;
        }
        for (i, o) in obs.iter().enumerate() {
            preprocess_obs(o, &mut x[..o.len()]);
            ro.last_values[i] = ppo.critic.infer(&x)[0];
        }
        navix::agents::gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            ppo.cfg.gamma,
            ppo.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        navix::agents::gae::normalize(&mut ro.advantages);
        ppo.update(&ro);
    }
    let wall = start.elapsed().as_secs_f64();
    report.row(&[
        "baseline-1".into(),
        "16".into(),
        format!("{wall:.2}"),
        format!("{:.0}", done_steps as f64 / wall),
        "-".into(),
    ]);
    report.meta("faults_injected", &faults.injected.to_string());
    report.meta("faults_recovered", &faults.recovered.to_string());
    simd_meta(&mut report);
    report.save();
    train.report.meta("faults_injected", &faults.injected.to_string());
    train.report.meta("faults_recovered", &faults.recovered.to_string());
    simd_meta(&mut train.report);
    train.report.save();
    println!("\n(paper §4.2: NAVIX 2048 agents ≈ 670M steps/s vs MiniGrid 3.1K steps/s;");
    println!(" compare the aggregate steps/s column here for the same crossover shape,");
    println!(" and BENCH_train.json for the serial/sharded/pipelined mode comparison)");
}
