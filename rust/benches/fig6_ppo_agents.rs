//! Paper Fig. 6: computation cost of training N PPO agents in parallel
//! (each with 16 envs). The paper trains up to 2048 agents × 1M steps on an
//! A100 in <50s (≈670M steps/s); this single-core testbed sweeps N ∈
//! {1,2,4,8} at `NAVIX_FIG6_STEPS` steps each (default 8192) and reports
//! the same accounting, plus the MiniGrid-baseline comparison (a single
//! PPO agent on the thread-per-env vector baseline).

use navix::agents::ppo::{Ppo, PpoConfig};
use navix::agents::preprocess_obs;
use navix::baseline::AsyncVectorEnv;
use navix::bench_harness::Report;
use navix::coordinator::multi_agent::train_parallel_ppo;
use navix::nn::sample_categorical;
use navix::rng::Key;

fn main() {
    let fast = std::env::var("NAVIX_BENCH_FAST").is_ok();
    let steps: u64 = std::env::var("NAVIX_FIG6_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 2048 } else { 8192 });
    let max_agents = if fast { 2 } else { 8 };
    let env_id = "Navix-Empty-8x8-v0";

    let mut report = Report::new(
        "fig6_ppo_agents",
        &["agents", "total_envs", "wall_s", "steps_per_s", "mean_return"],
    );

    // NAVIX engine: N agents in one process.
    let mut n = 1usize;
    while n <= max_agents {
        let r = train_parallel_ppo(env_id, n, 16, steps, 0).unwrap();
        report.row(&[
            format!("{n}"),
            format!("{}", n * 16),
            format!("{:.2}", r.wall_secs),
            format!("{:.0}", r.steps_per_second),
            format!("{:.3}", r.mean_final_return),
        ]);
        n *= 2;
    }

    // MiniGrid baseline: ONE agent doing FULL PPO training on the
    // thread-per-env vector baseline (the paper's "original implementation
    // trains a single PPO agent") — rollouts through the OO engine +
    // channel barrier, identical learner.
    let cfg = navix::make(env_id).unwrap();
    let d = navix::agents::OBS_DIM;
    let mut venv = AsyncVectorEnv::new(cfg, 16, Key::new(0));
    let mut obs = venv.reset();
    let mut ppo = Ppo::new(PpoConfig::default(), d, 7, 0);
    let mut rng = navix::rng::Rng::new(1);
    let t_len = ppo.cfg.rollout_len;
    let mut ro = navix::agents::ppo::Rollout::new(t_len, 16, d);
    let mut x = vec![0.0f32; d];
    let start = std::time::Instant::now();
    let mut done_steps = 0u64;
    let mut lp = vec![0.0f32; 7];
    while done_steps < steps {
        for t in 0..t_len {
            let mut actions = vec![0u8; 16];
            for (i, o) in obs.iter().enumerate() {
                preprocess_obs(o, &mut x);
                let logits = ppo.actor.infer(&x);
                let a = sample_categorical(&logits, &mut rng);
                navix::nn::log_softmax(&logits, &mut lp);
                let idx = t * 16 + i;
                ro.obs[idx * d..(idx + 1) * d].copy_from_slice(&x);
                ro.actions[idx] = a as u8;
                ro.logp[idx] = lp[a];
                ro.values[idx] = ppo.critic.infer(&x)[0];
                actions[i] = a as u8;
            }
            let r = venv.step(&actions);
            for i in 0..16 {
                let idx = t * 16 + i;
                ro.rewards[idx] = r.reward[i];
                ro.discounts[idx] = if r.terminated[i] { 0.0 } else { 1.0 };
                ro.boundaries[idx] = r.terminated[i] || r.truncated[i];
            }
            obs = r.obs;
            done_steps += 16;
        }
        for (i, o) in obs.iter().enumerate() {
            preprocess_obs(o, &mut x);
            ro.last_values[i] = ppo.critic.infer(&x)[0];
        }
        navix::agents::gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            ppo.cfg.gamma,
            ppo.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        navix::agents::gae::normalize(&mut ro.advantages);
        ppo.update(&ro);
    }
    let wall = start.elapsed().as_secs_f64();
    report.row(&[
        "baseline-1".into(),
        "16".into(),
        format!("{wall:.2}"),
        format!("{:.0}", done_steps as f64 / wall),
        "-".into(),
    ]);
    report.save();
    println!("\n(paper §4.2: NAVIX 2048 agents ≈ 670M steps/s vs MiniGrid 3.1K steps/s;");
    println!(" compare the aggregate steps/s column here for the same crossover shape)");
}
