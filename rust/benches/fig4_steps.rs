//! Paper Fig. 4: how the speedup varies with the number of steps
//! (1K → 1M) on MiniGrid-Empty-8x8-v0, 8 envs, 5 seeds.
//!
//! Default caps the largest point at 100K steps (the trend is established
//! well before 1M on this host); set `NAVIX_FIG4_MAX=1000000` for the full
//! paper protocol, `NAVIX_BENCH_FAST=1` for a smoke run.

use navix::bench_harness::{bench, simd_meta, Report};
use navix::coordinator::{unroll_walltime, Engine};

fn main() {
    let fast = std::env::var("NAVIX_BENCH_FAST").is_ok();
    let max_steps: usize = std::env::var("NAVIX_FIG4_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 1_000 } else { 100_000 });
    let runs = if fast { 1 } else { 5 };
    let env_id = "Navix-Empty-8x8-v0";
    let n_envs = 8;

    let mut report = Report::new(
        "fig4_steps",
        &["steps", "navix_median", "minigrid_median", "speedup"],
    );
    report.meta("agents_per_slot", "1");
    simd_meta(&mut report);
    let mut steps = 1_000usize;
    while steps <= max_steps {
        // fewer repeats for the long runs, like the paper's error bars
        let r = if steps >= 100_000 { runs.min(2) } else { runs };
        let navix = bench(0, r, || {
            unroll_walltime(Engine::Batched, env_id, n_envs, steps, 0).unwrap();
        });
        let baseline = bench(0, r, || {
            unroll_walltime(Engine::BaselineAsync, env_id, n_envs, steps, 0).unwrap();
        });
        report.row(&[
            steps.to_string(),
            navix.fmt_secs(),
            baseline.fmt_secs(),
            format!("{:.1}x", baseline.median / navix.median),
        ]);
        steps *= 10;
    }
    report.save();
    println!("\n(paper Fig. 4 shape: both curves linear in steps, constant gap)");
}
