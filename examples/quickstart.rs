//! Quickstart: the paper's Code-1 interaction pattern, in Rust.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Creates a batched `Navix-Empty-8x8-v0`, steps it with random actions,
//! prints the timestep fields (the paper's
//! `(t, o_t, a_t, r_{t+1}, γ_{t+1}, s_t, info)` tuple) and an ASCII render.

use navix::batch::BatchedEnv;
use navix::rng::{Key, Rng};
use navix::Action;

fn main() -> anyhow::Result<()> {
    // nx.make("Navix-Empty-8x8-v0") — the paper's Code 1.
    let cfg = navix::make("Navix-Empty-8x8-v0")?;
    println!(
        "made {} ({}x{}, obs={}, T={})",
        cfg.id,
        cfg.h,
        cfg.w,
        cfg.obs.kind.name(),
        cfg.max_steps
    );

    // env.reset(key): 4 parallel environments.
    let mut env = BatchedEnv::new(cfg.clone(), 4, Key::new(0));
    println!("\nreset -> step_type={:?} action={} reward={}",
        env.timestep.step_type[0], env.timestep.action[0], env.timestep.reward[0]);

    // interact: timestep = env.step(timestep, action, key)
    let mut rng = Rng::new(7);
    for t in 0..10 {
        let actions: Vec<u8> = (0..4).map(|_| rng.below(7) as u8).collect();
        env.step(&actions);
        let ts = env.timestep.get(0);
        println!(
            "t={:<3} action={:<8} reward={:+.1} discount={:.1} {:?}",
            ts.t,
            Action::from_u8(actions[0]).name(),
            ts.reward,
            ts.discount,
            ts.step_type,
        );
        if t == 9 {
            // full-grid symbolic view of env 0, rendered as ASCII
            let mut sym = vec![0i32; cfg.h * cfg.w * 3];
            navix::systems::observations::symbolic(&env.state.slot(0), &mut sym);
            println!("\nenv 0 state:");
            for r in 0..cfg.h {
                let row: String = (0..cfg.w)
                    .map(|c| match sym[(r * cfg.w + c) * 3] {
                        2 => '#',
                        8 => 'G',
                        10 => ['>', 'v', '<', '^']
                            [sym[(r * cfg.w + c) * 3 + 2].rem_euclid(4) as usize],
                        _ => '.',
                    })
                    .collect();
                println!("  {row}");
            }
        }
    }

    // first-person observation of env 0 (what an agent sees)
    let obs = env.obs.env_i32(4, 0);
    println!("\nfirst-person symbolic obs (7x7 tag channel):");
    for vr in 0..7 {
        let row: String =
            (0..7).map(|vc| char::from_digit(obs[(vr * 7 + vc) * 3] as u32 % 16, 16).unwrap()).collect();
        println!("  {row}");
    }
    println!("\nquickstart OK");
    Ok(())
}
