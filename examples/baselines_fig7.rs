//! Paper Fig. 7 / §4.3: DDQN, PPO and SAC baselines with tuned
//! hyperparameters, recorded to the scoreboard.
//!
//! The paper's full protocol is 10M steps × 32 seeds with 32-iteration
//! random search × 16 seeds per candidate; this driver runs the same
//! pipeline at a budget scaled to the host (defaults: 60k steps, 3 seeds,
//! and `--tune` enables an 8-iteration random search). Paper-scale budgets
//! are a flag away.
//!
//! ```text
//! cargo run --release --example baselines_fig7 -- \
//!     --envs Navix-Empty-5x5-v0,Navix-Empty-8x8-v0 --steps 60000 --seeds 3 [--tune]
//! ```

use navix::agents::tuning::{self, Sample};
use navix::agents::{Dqn, DqnConfig, Ppo, PpoConfig, Sac, SacConfig};
use navix::batch::BatchedEnv;
use navix::bench_harness::{Report, Summary};
use navix::cli::Args;
use navix::coordinator::scoreboard::{Entry, Scoreboard};
use navix::nn::Activation;
use navix::rng::Key;

const OBS: usize = navix::agents::OBS_DIM;
const ACTS: usize = 7;

fn act_of(s: &Sample) -> Activation {
    if s.get("activation") > 0.5 {
        Activation::Tanh
    } else {
        Activation::Relu
    }
}

fn run_ppo(env_id: &str, steps: u64, seed: u64, hp: Option<&Sample>) -> anyhow::Result<f32> {
    let mut cfg = PpoConfig::default();
    if let Some(s) = hp {
        cfg.lr = s.get_f32("lr");
        cfg.num_envs = s.get_usize("num_envs");
        cfg.rollout_len = s.get_usize("rollout_len");
        cfg.epochs = s.get_usize("epochs");
        cfg.minibatches = s.get_usize("minibatches");
        cfg.gamma = s.get_f32("gamma");
        cfg.gae_lambda = s.get_f32("gae_lambda");
        cfg.max_grad_norm = s.get_f32("max_grad_norm");
        cfg.activation = act_of(s);
    }
    let mut env = BatchedEnv::new(navix::make(env_id)?, cfg.num_envs, Key::new(seed));
    let mut agent = Ppo::new(cfg, OBS, ACTS, seed);
    Ok(agent.train(&mut env, steps).final_return())
}

fn run_dqn(env_id: &str, steps: u64, seed: u64, hp: Option<&Sample>) -> anyhow::Result<f32> {
    // Budget-scaled schedule (the paper runs 10M steps; these defaults are
    // the Table-9-style tuning outcome for short CPU budgets: faster lr,
    // quicker target refresh, shorter exploration anneal).
    let mut cfg = DqnConfig {
        learning_starts: 500,
        lr: 1e-3,
        target_update_freq: 500,
        exploration_fraction: 0.4,
        parallel_steps: 64,
        ..Default::default()
    };
    if let Some(s) = hp {
        cfg.lr = s.get_f32("lr");
        cfg.batch_size = s.get_usize("batch_size");
        cfg.target_update_freq = s.get_usize("target_update_freq");
        cfg.gamma = s.get_f32("gamma");
        cfg.exploration_fraction = s.get_f32("exploration_fraction");
        cfg.final_eps = s.get_f32("final_eps");
        cfg.max_grad_norm = s.get_f32("max_grad_norm");
        cfg.activation = act_of(s);
    }
    let mut env = BatchedEnv::new(navix::make(env_id)?, 16, Key::new(seed));
    let mut agent = Dqn::new(cfg, OBS, ACTS, seed);
    Ok(agent.train(&mut env, steps).final_return())
}

fn run_sac(env_id: &str, steps: u64, seed: u64, hp: Option<&Sample>) -> anyhow::Result<f32> {
    let mut cfg = SacConfig { learning_starts: 500, lr: 1e-3, parallel_steps: 64, ..Default::default() };
    if let Some(s) = hp {
        cfg.lr = s.get_f32("lr");
        cfg.batch_size = s.get_usize("batch_size");
        cfg.gamma = s.get_f32("gamma");
        cfg.tau = s.get_f32("tau");
        cfg.target_entropy_ratio = s.get_f32("target_entropy_ratio");
        cfg.activation = act_of(s);
    }
    let mut env = BatchedEnv::new(navix::make(env_id)?, 16, Key::new(seed));
    let mut agent = Sac::new(cfg, OBS, ACTS, seed);
    Ok(agent.train(&mut env, steps).final_return())
}

fn main() -> anyhow::Result<()> {
    let args = navix::cli::Args::parse(std::env::args().skip(1))?;
    let envs = args.opt_or("envs", "Navix-Empty-5x5-v0,Navix-Empty-6x6-v0,Navix-Empty-8x8-v0");
    let steps = args.opt_u64("steps", 60_000)?;
    let n_seeds = args.opt_u64("seeds", 3)?;
    let tune = args.switch("tune");
    let tune_iters = args.opt_usize("tune-iters", 8)?;
    let tune_steps = args.opt_u64("tune-steps", 20_000)?;

    let mut report =
        Report::new("fig7_baselines", &["env", "algo", "mean_return", "p5", "p95", "seeds"]);
    let mut sb = Scoreboard::load("results/scoreboard.tsv")?;

    for env_id in envs.split(',') {
        for algo in ["ppo", "dqn", "sac"] {
            type Runner = fn(&str, u64, u64, Option<&Sample>) -> anyhow::Result<f32>;
            let (runner, space): (Runner, _) = match algo {
                "ppo" => (run_ppo as Runner, tuning::ppo_space()),
                "dqn" => (run_dqn as Runner, tuning::dqn_space()),
                _ => (run_sac as Runner, tuning::sac_space()),
            };
            // optional random-search HP tuning (paper §4.3 protocol, scaled)
            let best_hp = if tune {
                let (best, score) = tuning::random_search(&space, tune_iters, 42, |s| {
                    (0..2)
                        .map(|seed| runner(env_id, tune_steps, seed, Some(s)).unwrap_or(-1.0))
                        .sum::<f32>() as f64
                        / 2.0
                });
                println!("tuned {algo}/{env_id}: score {score:.3} {best:?}");
                Some(best)
            } else {
                None
            };
            let returns: Vec<f64> = (0..n_seeds)
                .map(|seed| runner(env_id, steps, seed, best_hp.as_ref()).map(|r| r as f64))
                .collect::<anyhow::Result<_>>()?;
            let s = Summary::from_samples(&returns);
            report.row(&[
                env_id.to_string(),
                algo.to_string(),
                format!("{:.3}", s.mean),
                format!("{:.3}", s.p5),
                format!("{:.3}", s.p95),
                n_seeds.to_string(),
            ]);
            sb.record(Entry {
                env_id: env_id.to_string(),
                algo: algo.to_string(),
                seeds: n_seeds as u32,
                env_steps: steps,
                final_return: s.mean as f32,
            });
        }
    }
    report.save();
    sb.save()?;
    println!("\nscoreboard written to results/scoreboard.tsv");
    Ok(())
}
