//! Render one rgb frame of every environment family to `gallery/*.ppm` —
//! visual validation of layouts, sprites and the rgb observation functions
//! (convert with `magick gallery/*.ppm` or open directly).
//!
//! ```text
//! cargo run --release --example render_gallery [-- --seed 3]
//! ```

use navix::batch::BatchedEnv;
use navix::cli::Args;
use navix::rng::Key;
use navix::systems::observations::ObsKind;
use navix::systems::render::write_ppm;
use navix::systems::sprites::TILE;

const GALLERY: [&str; 10] = [
    "Navix-Empty-8x8-v0",
    "Navix-DoorKey-8x8-v0",
    "Navix-FourRooms-v0",
    "Navix-KeyCorridorS3R3-v0",
    "Navix-LavaGapS7-v0",
    "Navix-SimpleCrossingS9N3-v0",
    "Navix-LavaCrossingS9N1-v0",
    "Navix-Dynamic-Obstacles-8x8",
    "Navix-DistShift2-v0",
    "Navix-GoToDoor-8x8-v0",
];

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let seed = args.opt_u64("seed", 0)?;
    for id in GALLERY {
        let cfg = navix::make(id)?.with_observation(ObsKind::Rgb);
        let env = BatchedEnv::new(cfg.clone(), 1, Key::new(seed));
        let rgb = env.obs.env_u8(1, 0);
        let path = format!("gallery/{}.ppm", id.replace("Navix-", ""));
        write_ppm(&path, cfg.w * TILE, cfg.h * TILE, rgb)?;
        println!("wrote {path} ({}x{})", cfg.w * TILE, cfg.h * TILE);
    }
    // one first-person frame too
    let cfg = navix::make("Navix-DoorKey-8x8-v0")?.with_observation(ObsKind::RgbFirstPerson);
    let env = BatchedEnv::new(cfg, 1, Key::new(seed));
    write_ppm("gallery/DoorKey-8x8-first-person.ppm", 7 * TILE, 7 * TILE, env.obs.env_u8(1, 0))?;
    println!("wrote gallery/DoorKey-8x8-first-person.ppm");
    Ok(())
}
