//! The paper's §4.2 headline workload, scaled to this testbed: train N
//! independent PPO agents — each with its own 16-env batch — in one process
//! and report aggregate steps/second (paper Fig. 6).
//!
//! ```text
//! cargo run --release --example parallel_agents -- --agents 4 --steps 20000
//! cargo run --release --example parallel_agents -- --sharded --shards 2
//! ```
//!
//! `--sharded` steps every agent's env batch on the multi-core sharded
//! engine (`--shards`/`--threads` as in `throughput_sweep`); trajectories
//! are bit-identical to the default single-threaded engine.

use navix::bench_harness::Report;
use navix::cli::Args;
use navix::coordinator::multi_agent::train_parallel_ppo_exec;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let env_id = args.opt_or("env", "Navix-Empty-8x8-v0");
    let max_agents = args.opt_usize("agents", 4)?;
    let steps = args.opt_u64("steps", 20_000)?;
    let envs_per_agent = args.opt_usize("envs-per-agent", 16)?;
    // --sharded alone means auto shard/thread counts (one per core); any
    // explicit --shards/--threads also opts in.
    let sharded =
        args.switch("sharded") || args.opt("shards").is_some() || args.opt("threads").is_some();
    let exec = if sharded { Some(args.exec_config()?) } else { None };

    let mut report = Report::new(
        "parallel_agents",
        &["agents", "envs", "steps/agent", "wall_s", "steps/s", "mean_return"],
    );
    let mut n = 1;
    while n <= max_agents {
        let r = train_parallel_ppo_exec(&env_id, n, envs_per_agent, steps, 0, exec)?;
        report.row(&[
            n.to_string(),
            (n * envs_per_agent).to_string(),
            steps.to_string(),
            format!("{:.2}", r.wall_secs),
            format!("{:.0}", r.steps_per_second),
            format!("{:.3}", r.mean_final_return),
        ]);
        n *= 2;
    }
    report.save();
    println!("\n(cf. paper Fig. 6: one A100 trains 2048 agents in <50s for 1M steps each;");
    println!(" this single-core testbed reproduces the shared-nothing structure and the");
    println!(" per-agent throughput accounting — see EXPERIMENTS.md §Fig6.)");
    Ok(())
}
