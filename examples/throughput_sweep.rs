//! Quick interactive version of the paper's Fig. 5: wall time of 1K unrolls
//! as the number of parallel environments grows, for the single-threaded
//! batched engine (`vmap` analog), the sharded multi-core engine (`pmap`
//! analog) and both baseline vector wrappers.
//!
//! ```text
//! cargo run --release --example throughput_sweep -- --max-batch 4096 --steps 1000
//! cargo run --release --example throughput_sweep -- --shards 4 --threads 4
//! ```
//!
//! `--shards S` / `--threads T` configure the sharded engine (absent or 0 =
//! one shard and one worker per available core). The sharded rows execute
//! the exact same action stream as the batched rows — the per-env RNG
//! streams are a function of the global env index, not the worker — so the
//! ratio between them is pure execution-layer speedup.

use navix::bench_harness::{stats::fmt_duration, Report};
use navix::cli::Args;
use navix::coordinator::{unroll_walltime_exec, Engine};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let env_id = args.opt_or("env", "Navix-Empty-8x8-v0");
    let max_batch = args.opt_usize("max-batch", 4096)?;
    let steps = args.opt_usize("steps", 1000)?;
    // thread-per-env baseline is capped: that's the paper's point
    let max_async = args.opt_usize("max-async", 128)?;
    let exec = args.exec_config()?;

    let mut report =
        Report::new("throughput_sweep", &["envs", "engine", "wall", "steps/s"]);
    let mut b = 1;
    while b <= max_batch {
        for engine in
            [Engine::Batched, Engine::Sharded, Engine::BaselineSync, Engine::BaselineAsync]
        {
            let is_baseline = matches!(engine, Engine::BaselineSync | Engine::BaselineAsync);
            if is_baseline && b > max_async {
                continue;
            }
            let secs = unroll_walltime_exec(engine, &env_id, b, steps, 0, &exec)?;
            report.row(&[
                b.to_string(),
                engine.name().to_string(),
                fmt_duration(secs),
                format!("{:.0}", (b * steps) as f64 / secs),
            ]);
        }
        b *= 4;
    }
    report.save();
    Ok(())
}
