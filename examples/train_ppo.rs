//! End-to-end validation driver (DESIGN.md): train a PPO agent on
//! `Navix-Empty-8x8-v0` through the **full three-layer stack** — rollouts on
//! the Rust SoA engine (L3), actor-critic forward and the fused PPO update
//! executed as AOT-compiled JAX+Pallas artifacts via PJRT (L2+L1) — and
//! assert the task is solved. Falls back report-only if artifacts are
//! missing.
//!
//! ```text
//! make artifacts && cargo run --release --example train_ppo [-- --steps 120000 --native]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use navix::agents::ppo::{Ppo, PpoConfig};
use navix::batch::BatchedEnv;
use navix::cli::Args;
use navix::coordinator::XlaPpo;
use navix::rng::Key;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let env_id = args.opt_or("env", "Navix-Empty-8x8-v0");
    let steps = args.opt_u64("steps", 120_000)?;
    let seed = args.opt_u64("seed", 0)?;
    let native = args.switch("baseline"); // --baseline = native-nn PPO

    let cfg = navix::make(&env_id)?;
    let num_envs = 16; // the paper's per-agent env count
    let mut env = BatchedEnv::new(cfg, num_envs, Key::new(seed));
    let t0 = std::time::Instant::now();

    let log = if native {
        println!("training native-nn PPO on {env_id} for {steps} steps…");
        let mut ppo =
            Ppo::new(PpoConfig { num_envs, ..Default::default() }, navix::agents::OBS_DIM, 7, seed);
        ppo.train(&mut env, steps)
    } else {
        println!("training XLA-fused PPO (L1 Pallas + L2 JAX via PJRT) on {env_id} for {steps} steps…");
        match XlaPpo::new(PpoConfig { num_envs, ..Default::default() }, seed) {
            Ok(mut ppo) => ppo.train(&mut env, steps)?,
            Err(e) => {
                eprintln!("XLA path unavailable ({e:#}); falling back to native PPO");
                let mut ppo = Ppo::new(
                    PpoConfig { num_envs, ..Default::default() },
                    navix::agents::OBS_DIM,
                    7,
                    seed,
                );
                ppo.train(&mut env, steps)
            }
        }
    };

    let dt = t0.elapsed().as_secs_f64();
    println!("\nloss / return curve:");
    let stride = (log.curve.len() / 15).max(1);
    for (i, p) in log.curve.iter().enumerate() {
        if i % stride == 0 || i + 1 == log.curve.len() {
            println!(
                "  step {:>8}  mean_return {:>6.3}  loss {:>9.4}",
                p.env_steps, p.mean_return, p.loss
            );
        }
    }
    let final_return = log.final_return();
    println!(
        "\ntrained {} env steps in {:.1}s ({:.0} steps/s incl. learning), {} episodes",
        steps,
        dt,
        steps as f64 / dt,
        log.episodes
    );
    println!("final mean episodic return: {final_return:.3}");

    // Empty-8x8 is solved when the agent reliably reaches the goal (+1).
    anyhow::ensure!(
        final_return > 0.8,
        "end-to-end validation FAILED: final return {final_return:.3} <= 0.8"
    );
    println!("end-to-end validation PASSED (return > 0.8)");
    Ok(())
}
