//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The flagship three-layer path (`rust/src/runtime`, `coordinator::trainer`)
//! executes AOT-compiled JAX/Pallas artifacts through PJRT via the `xla`
//! crate. That crate links the native `xla_extension` library, which cannot
//! be vendored in this offline image — so this stub provides the exact API
//! surface the codebase uses, with every runtime entry point returning a
//! descriptive error. The effect:
//!
//! * everything compiles and the full native test suite runs offline;
//! * `Runtime::cpu()` fails fast with a clear message, so the PJRT-backed
//!   paths (`navix train --algo ppo-xla`, `examples/train_ppo` XLA mode,
//!   `rust/tests/test_runtime.rs`) degrade or skip gracefully;
//! * swapping this path dependency for the real bindings re-enables the
//!   runtime with zero source changes.

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` lifts it into
/// `anyhow::Error` at the call sites).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build — the `xla` dependency is the offline \
         stub (vendor/xla); swap it for real xla bindings to enable the runtime"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// A host-side literal (stub: carries no data; construction succeeds so
/// argument marshalling code compiles, every accessor errors).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Per-device, per-output buffers in the real API; here: always an error.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub): creation fails fast, which is the single gate the
/// downstream code checks.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("offline"), "message should explain the stub: {msg}");
        assert!(msg.contains("vendor/xla"), "message should point at the swap: {msg}");
    }

    #[test]
    fn literal_accessors_error() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let s = Literal::scalar(3i32);
        assert!(s.to_vec::<i32>().is_err());
    }
}
