//! A minimal, dependency-free, call-compatible subset of the `anyhow`
//! error-handling crate.
//!
//! This workspace builds in an offline image with no crates.io registry, so
//! the subset the codebase actually uses is vendored here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!`
//! / `ensure!` macros. The semantics match upstream where it matters:
//!
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain;
//! * `.context(..)` / `.with_context(..)` prepend higher-level messages and
//!   also lift `Option` into `Result`;
//! * `Display` prints the outermost message, `{:#}` prints the whole chain
//!   colon-separated, and `Debug` prints the chain in the upstream
//!   "Caused by:" layout.
//!
//! Swap this path dependency for the real `anyhow` when building with
//! network access; no call sites need to change.

use std::fmt;

/// An error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with a higher-level context message (the upstream
    /// `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first (mirrors upstream
    /// `Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, like upstream.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as upstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_prepends_and_alternate_prints_chain() {
        let e: Result<()> = Err(io_err()).context("reading config");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context_lifts_none() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let some: Option<u32> = Some(7);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_format_and_return() {
        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(guard(5).unwrap(), 5);
        assert_eq!(format!("{}", guard(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", guard(200).unwrap_err()), "x too big: 200");
        let e = anyhow!("plain {}", 42);
        assert_eq!(format!("{e}"), "plain 42");
    }

    #[test]
    fn debug_prints_caused_by() {
        let e: Result<()> = Err(io_err()).context("outer");
        let s = format!("{:?}", e.unwrap_err());
        assert!(s.starts_with("outer"));
        assert!(s.contains("Caused by:"));
        assert!(s.contains("gone"));
    }
}
