# navix-rs — build/verify entry points.
#
# `artifacts` runs the Python AOT layer (JAX model + Pallas kernels → HLO
# text) that the Rust PJRT runtime consumes; Python is never on the request
# path afterwards. The Rust targets work without artifacts — PJRT-backed
# paths degrade or skip gracefully (see rust/src/runtime/mod.rs).

.PHONY: build test verify artifacts bench-smoke train-smoke bench-nightly simd-check fmt clippy

build:
	cargo build --release

test:
	cargo test -q

# Tier-1 verify: exactly what CI's test job runs.
verify:
	cargo build --release && cargo test -q

# AOT-lower the JAX/Pallas layers to rust/artifacts/*.hlo.txt (needs jax).
# The out-dir is the crate root so artifact discovery works from both the
# repo root and the cwd cargo gives test binaries (rust/); override with
# NAVIX_ARTIFACTS to load from elsewhere.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

bench-smoke:
	cargo bench --bench fig5_batch -- --smoke
	cargo bench --bench fig5_sharded -- --smoke
	cargo bench --bench obs_throughput -- --smoke

# Exactly what CI's train-smoke job runs: end-to-end PPO training
# throughput (serial vs sharded vs pipelined, all on the fused scan path),
# BENCH_train.json, and the bench_floors.toml [train] steps/s gate
# (NAVIX_TRAIN_SMOKE_FLOOR overrides).
train-smoke:
	cargo bench --bench fig6_ppo_agents -- --smoke

# Exactly what the nightly workflow runs: the full non-smoke bench suite
# (every batch size / obs kind / agent count), writing the BENCH_*.json
# trajectory files the committed floors are raised against.
bench-nightly:
	cargo bench --bench fig5_sharded
	cargo bench --bench obs_throughput
	cargo bench --bench fig6_ppo_agents

# The CI simd-matrix job, locally: every forced kernel path (scalar, sse2,
# avx2) must be bitwise identical to the oracles — obs parity (overlay vs
# scan, registry + odd-shape tails + engine end-to-end), fused-scan parity,
# the nn::mlp GEMM tests and the simd:: dispatch pins. Paths the CPU lacks
# are clamped by the dispatcher (the run still passes, but re-tests a
# narrower kernel — CI's probe skips those legs instead).
simd-check:
	for path in scalar sse2 avx2; do \
		echo "=== NAVIX_SIMD=$$path ==="; \
		NAVIX_SIMD=$$path cargo test --test test_obs_parity -- --nocapture && \
		NAVIX_SIMD=$$path cargo test --test test_scan_parity -- --nocapture && \
		NAVIX_SIMD=$$path cargo test --lib nn::mlp -- --nocapture && \
		NAVIX_SIMD=$$path cargo test --lib simd:: -- --nocapture || exit 1; \
	done

fmt:
	cargo fmt --all

clippy:
	cargo clippy --all-targets -- -D warnings
